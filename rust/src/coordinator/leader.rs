//! Leader side: broadcast config, run own share, gather reports.
//!
//! Both control-plane exchanges route through the
//! [`crate::collective`] subsystem. The config broadcast bootstraps
//! over the **star** algorithm (the config is what tells workers
//! which algorithm the run uses, so it cannot itself depend on the
//! choice); result aggregation runs under the configured `--coll`
//! algorithm over the triples topology. Under `--coll star` both
//! exchanges are bit-for-bit the legacy wire protocol (tags
//! [`tags::CONFIG`] / [`tags::RESULT`] included, via
//! [`TagSpace::with_star_tag`]).

use super::results::{RunConfig, WorkerReport};
use super::worker::run_configured_stream;
use crate::collective::{Collective, TagSpace, Topology};
use crate::comm::datapath::{ChunkStream, ChunkTag};
use crate::comm::{tags, CommError, Decode, Encode, Result, Transport};
use crate::obs::fold::{FoldStream, TraceFold};
use crate::stream::{aggregate, AggregateResult, StreamResult};

/// Tag epoch of the config broadcast in [`tags::NS_COLL`].
pub(crate) const EPOCH_CONFIG: u64 = 0;
/// Tag epoch of the result aggregation in [`tags::NS_COLL`].
pub(crate) const EPOCH_RESULT: u64 = 1;
/// Tag epoch of the worker→leader telemetry stream in
/// [`tags::NS_COLL`] (only used when the run config has `trace` set).
pub(crate) const EPOCH_TRACE: u64 = 2;

/// The config broadcast's tag space (star bootstrap, legacy tag).
pub(crate) fn config_space() -> TagSpace {
    TagSpace::with_star_tag(tags::NS_COLL, EPOCH_CONFIG, tags::CONFIG)
}

/// The result gather's tag space (configured algorithm, legacy star
/// tag).
pub(crate) fn result_space() -> TagSpace {
    TagSpace::with_star_tag(tags::NS_COLL, EPOCH_RESULT, tags::RESULT)
}

/// The telemetry stream's datapath tag: one [`ChunkStream`] per
/// worker, after the result gather.
pub(crate) fn trace_tag() -> ChunkTag {
    ChunkTag::new(tags::NS_COLL, EPOCH_TRACE)
}

fn telemetry_err(e: crate::json::JsonError) -> CommError {
    CommError::Malformed(format!("telemetry stream: {e}"))
}

/// Fold every worker's NDJSON telemetry stream — plus the leader's own
/// pending events — into one [`TraceFold`], with memory bounded by the
/// largest in-flight line per peer, not the report sizes: chunks from
/// all peers interleave in arrival order, each byte window feeding
/// that peer's incremental parse state. Returns the fold and the
/// worst per-stream peak resident parse bytes (the bound the tests
/// assert).
pub(crate) fn fold_worker_traces(t: &dyn Transport, np: usize) -> Result<(TraceFold, usize)> {
    let mut fold = TraceFold::new();
    let mut own = FoldStream::new();
    own.feed(&mut fold, crate::obs::emit::render_pending().as_bytes())
        .map_err(telemetry_err)?;
    own.finish(&mut fold).map_err(telemetry_err)?;
    let mut peak = own.peak_resident_bytes();
    let peers: Vec<usize> = (1..np).collect();
    if !peers.is_empty() {
        let mut streams: Vec<FoldStream> =
            (0..peers.len()).map(|_| FoldStream::new()).collect();
        ChunkStream::drain_chunks(t, &peers, trace_tag(), |c| {
            streams[c.peer_idx].feed(&mut fold, c.payload()).map_err(telemetry_err)
        })?;
        for s in &mut streams {
            s.finish(&mut fold).map_err(telemetry_err)?;
            peak = peak.max(s.peak_resident_bytes());
        }
    }
    Ok((fold, peak))
}

/// Run a coordinated STREAM benchmark from PID 0's endpoint.
///
/// Broadcasts `cfg`, runs PID 0's own share, gathers every worker's
/// report, and returns (aggregate, per-process results).
///
/// Under `--heartbeat`, a monitor thread runs the
/// [`Detector`](crate::fault::Detector) alongside the body: workers
/// echo its pings for their whole lifecycle, and if the body then
/// fails (a gather stalled on a silent rank), the error is upgraded
/// from a generic timeout to [`CommError::RankDead`] naming the first
/// rank the detector declared dead — the actionable verdict a caller
/// needs to reap, redeal, or resume.
pub fn run_leader(
    t: &dyn Transport,
    cfg: &RunConfig,
) -> Result<(AggregateResult, Vec<StreamResult>)> {
    assert_eq!(t.pid(), 0, "run_leader must be called on PID 0");
    let np = t.np();
    if cfg.trace {
        crate::obs::set_thread_rank(0);
        crate::obs::set_enabled(true);
    }
    Collective::star(np).bcast(t, config_space(), cfg.to_bytes())?;
    if !cfg.heartbeat {
        return finish_leader(t, cfg, np);
    }
    let hb = crate::fault::DetectorConfig::from_env();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let dead = std::sync::Mutex::new(Vec::new());
    let out = std::thread::scope(|s| {
        s.spawn(|| {
            let mut det = crate::fault::Detector::new(0, np, hb.clone());
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                match det.probe(t) {
                    Ok(newly) if !newly.is_empty() => {
                        dead.lock().unwrap().extend(newly);
                    }
                    Ok(_) => {}
                    Err(_) => break,
                }
            }
        });
        let r = finish_leader(t, cfg, np);
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        r
    });
    match out {
        Err(e) => {
            let dead = dead.into_inner().unwrap();
            match dead.first() {
                Some(&pid) => Err(CommError::RankDead { pid, missed: hb.miss_threshold }),
                None => Err(e),
            }
        }
        ok => ok,
    }
}

/// The post-broadcast leader body: own share, result gather, telemetry
/// fold — factored out so `run_leader` can run it under the failure
/// detector's monitor thread.
fn finish_leader(
    t: &dyn Transport,
    cfg: &RunConfig,
    np: usize,
) -> Result<(AggregateResult, Vec<StreamResult>)> {
    let mut results = Vec::with_capacity(np);
    results.push(run_configured_stream(cfg, 0, np));
    let coll = Collective::new(cfg.coll, Topology::grouped(np, cfg.nppn));
    let my_report = WorkerReport::from_result(0, &results[0]);
    let parts = coll
        .gather(t, result_space(), my_report.to_bytes())?
        .expect("pid 0 is the gather root");
    for part in &parts[1..] {
        results.push(WorkerReport::from_bytes(part)?.to_result());
    }
    let agg = aggregate(&results).expect("np >= 1");
    if cfg.trace {
        let (fold, peak) = fold_worker_traces(t, np)?;
        let dropped: u64 = fold.ranks.values().map(|r| r.dropped).sum();
        let hist_samples: u64 = fold
            .ranks
            .values()
            .flat_map(|r| r.hists.values())
            .map(|h| h.count)
            .sum();
        crate::log!(
            Info,
            "telemetry: folded {} events from {} rank streams ({} lines, {} hist samples, {} dropped, peak resident {} B)",
            fold.total_events(),
            fold.ranks.len(),
            fold.lines,
            hist_samples,
            dropped,
            peak
        );
        if fold.unknown_kinds > 0 {
            crate::log!(
                Warn,
                "telemetry: {} event(s) carry kinds this build doesn't know (schema drift)",
                fold.unknown_kinds
            );
        }
        crate::obs::clear_thread_rank();
    }
    Ok((agg, results))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use crate::coordinator::results::{EngineKind, MapKind};
    use crate::coordinator::worker::run_worker;
    use crate::stream::STREAM_Q;
    use std::thread;

    fn cfg(n: usize, nt: usize, map: MapKind) -> RunConfig {
        RunConfig {
            n_global: n,
            nt,
            q: STREAM_Q,
            map,
            engine: EngineKind::Native,
            dtype: crate::element::Dtype::F64,
            backend: crate::backend::BackendKind::Host,
            threads: 1,
            coll: crate::collective::CollKind::Star,
            nppn: 0,
            chunk_bytes: 0,
            artifacts: "artifacts".into(),
            trace: false,
            heartbeat: false,
            checkpoint: String::new(),
            restore: false,
            transport: crate::comm::TransportKind::Channel,
            recv_timeout_ms: 0,
        }
    }

    #[test]
    fn leader_and_workers_coordinate_over_channels() {
        let np = 4;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let mut handles = Vec::new();
        for t in world {
            handles.push(thread::spawn(move || run_worker(&t).unwrap()));
        }
        let (agg, results) = run_leader(&leader, &cfg(1 << 14, 3, MapKind::Block)).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(agg.np, np);
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        let covered: usize = results.iter().map(|r| r.n_local).sum();
        assert_eq!(covered, 1 << 14);
    }

    #[test]
    fn cyclic_map_through_the_full_protocol() {
        let np = 3;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let (agg, _) = run_leader(&leader, &cfg(3000, 2, MapKind::Cyclic)).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert!(agg.all_valid);
    }

    #[test]
    fn single_process_world() {
        let mut world = ChannelHub::world(1);
        let leader = world.pop().unwrap();
        let (agg, _) = run_leader(&leader, &cfg(4096, 2, MapKind::Block)).unwrap();
        assert!(agg.all_valid);
        assert!(leader.stats().is_silent(), "np=1 needs no messages");
    }

    /// The `--backend threaded` acceptance path: a coordinated run
    /// completes, validates, and every per-process result names the
    /// backend that produced it.
    #[test]
    fn threaded_backend_through_the_full_protocol() {
        use crate::backend::BackendKind;
        let np = 3;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let mut c = cfg(3 * 4096, 3, MapKind::Block);
        c.backend = BackendKind::Threaded;
        c.threads = 2;
        let (agg, results) = run_leader(&leader, &c).unwrap();
        for h in handles {
            let rep = h.join().unwrap();
            assert_eq!(rep.backend, BackendKind::Threaded);
        }
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(agg.backend, BackendKind::Threaded);
        for r in &results {
            assert_eq!(r.backend, BackendKind::Threaded);
        }
    }

    /// The `--coll` acceptance path: result aggregation over the
    /// tree, ring, and hierarchical algorithms produces the identical
    /// pid-ordered results the star protocol does.
    #[test]
    fn collective_algorithms_through_the_full_protocol() {
        use crate::collective::CollKind;
        for (kind, nppn) in [(CollKind::Tree, 0), (CollKind::Ring, 0), (CollKind::Hier, 2)] {
            let np = 5;
            let mut world = ChannelHub::world(np);
            let leader = world.remove(0);
            let handles: Vec<_> = world
                .into_iter()
                .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
                .collect();
            let mut c = cfg(5 * 1024, 2, MapKind::Block);
            c.coll = kind;
            c.nppn = nppn;
            let (agg, results) = run_leader(&leader, &c).unwrap();
            for h in handles {
                h.join().unwrap();
            }
            assert!(agg.all_valid, "coll {kind}: worst err {}", agg.worst_err);
            assert_eq!(agg.np, np);
            assert_eq!(results.iter().map(|r| r.n_local).sum::<usize>(), 5 * 1024);
        }
    }

    /// `--trace` rides the protocol: every worker streams its NDJSON
    /// telemetry to the leader after the result gather, and the
    /// leader's bounded-memory fold consumes them without breaking the
    /// run. Works whether or not recording is compiled in (under
    /// `obs-off` the streams carry only meta lines).
    #[test]
    fn traced_run_folds_worker_telemetry_in_lockstep() {
        let np = 4;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let mut c = cfg(1 << 12, 2, MapKind::Cyclic);
        c.trace = true;
        let (agg, results) = run_leader(&leader, &c).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(results.len(), np);
    }

    /// `--heartbeat` rides the protocol: the leader's detector probes
    /// while workers compute and respond, nobody is declared dead, and
    /// the run completes exactly as without it.
    #[test]
    fn heartbeat_run_completes_clean_when_all_alive() {
        let np = 3;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let mut c = cfg(3 * 1024, 2, MapKind::Block);
        c.heartbeat = true;
        let (agg, _) = run_leader(&leader, &c).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
    }

    /// `--checkpoint` rides the protocol: a coordinated run leaves one
    /// valid `ckpt_v1` shard per rank, and a `--restore` run resumes
    /// from them and still validates.
    #[test]
    fn checkpointed_run_writes_shards_and_restores() {
        use crate::fault::ckpt::{read_shard, shard_path};
        let np = 2;
        let dir = std::env::temp_dir()
            .join(format!("distarray_coord_ckpt_{}", std::process::id()));
        let run = |restore: bool| {
            let mut world = ChannelHub::world(np);
            let leader = world.remove(0);
            let handles: Vec<_> = world
                .into_iter()
                .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
                .collect();
            let mut c = cfg(2 * 2048, 3, MapKind::Block);
            c.checkpoint = dir.display().to_string();
            c.restore = restore;
            let (agg, _) = run_leader(&leader, &c).unwrap();
            for h in handles {
                h.join().unwrap();
            }
            assert!(agg.all_valid, "worst err {}", agg.worst_err);
        };
        run(false);
        for pid in 0..np {
            assert!(shard_path(&dir, pid).exists(), "rank {pid} shard missing");
            let s = read_shard::<f64>(&dir, pid).unwrap();
            assert_eq!((s.np, s.epoch, s.n_global), (np, 3, 2 * 2048));
        }
        run(true);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f32_dtype_through_the_full_protocol() {
        let np = 3;
        let mut world = ChannelHub::world(np);
        let leader = world.remove(0);
        let handles: Vec<_> = world
            .into_iter()
            .map(|t| thread::spawn(move || run_worker(&t).unwrap()))
            .collect();
        let mut c = cfg(3 * 1024, 4, MapKind::Block);
        c.dtype = crate::element::Dtype::F32;
        let (agg, results) = run_leader(&leader, &c).unwrap();
        for h in handles {
            h.join().unwrap();
        }
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(agg.width, 4, "aggregate must carry the f32 width");
        for r in &results {
            assert_eq!(r.width, 4);
        }
    }
}
