//! Bounded-memory folding of NDJSON telemetry streams.
//!
//! The leader (and `repro trace-report`) must aggregate per-rank
//! traces without ever holding a whole report: [`FoldStream`] pushes
//! raw bytes through the incremental [`StreamDocs`] parser and folds
//! each completed line into a [`TraceFold`] — peak memory is bounded
//! by the largest in-flight line, not `O(P · report)`.

use super::hist::HistSnapshot;
use crate::json::{Json, JsonError, StreamDocs};
use std::collections::BTreeMap;

/// Rolling aggregate for one event kind (or one collective phase).
#[derive(Debug, Default, Clone, Copy)]
pub struct KindAgg {
    pub count: u64,
    pub total_dur_ns: u64,
    /// Sum of the kind's primary payload (`bytes` for data-movement
    /// kinds, `value` deltas are not folded here).
    pub total_bytes: u64,
}

impl KindAgg {
    fn add(&mut self, dur_ns: u64, bytes: u64) {
        self.count += 1;
        self.total_dur_ns += dur_ns;
        self.total_bytes += bytes;
    }

    /// Aggregate throughput over the recorded span time.
    pub fn gb_per_sec(&self) -> f64 {
        if self.total_dur_ns == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.total_dur_ns as f64
        }
    }
}

/// Per-rank rollup of a trace stream.
#[derive(Debug, Default, Clone)]
pub struct RankAgg {
    pub kinds: BTreeMap<String, KindAgg>,
    /// Collective activity split by phase name (`coll_op` events).
    pub phases: BTreeMap<&'static str, KindAgg>,
    pub t_min_ns: u64,
    pub t_max_ns: u64,
    /// Wall-clock anchor from the stream's `trace_meta_v1` line.
    pub wall_anchor_ns: u64,
    /// Ring drop count from the closing meta line.
    pub dropped: u64,
    pub events: u64,
    /// Runtime histograms from `trace_hist_v1` lines. Values are
    /// cumulative at emission, so the latest line wins.
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl RankAgg {
    /// Monotonic span covered by this rank's events, in seconds.
    pub fn wall_seconds(&self) -> f64 {
        self.t_max_ns.saturating_sub(self.t_min_ns) as f64 / 1e9
    }
}

/// Collective phase name from a `coll_op` step field
/// (`level | phase | round` packing — see
/// [`TagSpace::at`](crate::collective::TagSpace::at)).
pub fn phase_name(step: u64) -> &'static str {
    match (step >> 16) & 0xF {
        0 => "gather",
        1 => "bcast",
        2 => "up",
        3 => "down",
        4 => "dissem",
        5 => "reduce_scatter",
        6 => "allgather",
        _ => "other",
    }
}

/// Fleet-wide fold of trace streams: per-rank, per-kind aggregates
/// plus line accounting. Feed it from any number of sources (one
/// [`FoldStream`] each); memory is the aggregate tables only.
#[derive(Debug, Default)]
pub struct TraceFold {
    pub ranks: BTreeMap<i64, RankAgg>,
    /// Total NDJSON documents folded.
    pub lines: u64,
    /// Documents that were valid JSON but not a recognized trace
    /// schema (counted, not fatal — forward compatibility).
    pub unknown_lines: u64,
    /// `trace_event_v1` lines whose `kind` this build doesn't know —
    /// schema drift between builds must be visible, not silent.
    pub unknown_kinds: u64,
}

impl TraceFold {
    pub fn new() -> TraceFold {
        TraceFold::default()
    }

    /// Fold one parsed NDJSON document.
    pub fn add_doc(&mut self, doc: &Json) {
        self.lines += 1;
        let schema = doc.get("schema").and_then(|s| s.as_str());
        let rank = doc.get("rank").and_then(|r| r.as_f64()).map(|r| r as i64).unwrap_or(-1);
        match schema {
            Some("trace_meta_v1") => {
                let agg = self.ranks.entry(rank).or_default();
                if let Some(w) = doc.get("wall_anchor_ns").and_then(|v| v.as_f64()) {
                    agg.wall_anchor_ns = w as u64;
                }
                if let Some(d) = doc.get("dropped").and_then(|v| v.as_f64()) {
                    agg.dropped = d as u64;
                }
            }
            Some("trace_event_v1") => {
                let kind = doc.get("kind").and_then(|k| k.as_str()).unwrap_or("unknown");
                if super::kind_from_name(kind).is_none() {
                    self.unknown_kinds += 1;
                }
                let t_ns = doc.get("t_ns").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let dur = doc.get("dur_ns").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let bytes = doc.get("bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let agg = self.ranks.entry(rank).or_default();
                agg.events += 1;
                if agg.events == 1 || t_ns < agg.t_min_ns {
                    agg.t_min_ns = t_ns;
                }
                let end = t_ns + dur;
                if end > agg.t_max_ns {
                    agg.t_max_ns = end;
                }
                agg.kinds.entry(kind.to_string()).or_default().add(dur, bytes);
                if kind == "coll_op" {
                    let step = doc.get("step").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                    agg.phases.entry(phase_name(step)).or_default().add(dur, bytes);
                }
            }
            Some("trace_hist_v1") => {
                if let Some(name) = doc.get("hist").and_then(|h| h.as_str()) {
                    self.ranks
                        .entry(rank)
                        .or_default()
                        .hists
                        .insert(name.to_string(), HistSnapshot::from_doc(doc));
                }
            }
            _ => self.unknown_lines += 1,
        }
    }

    /// Total events folded across every rank.
    pub fn total_events(&self) -> u64 {
        self.ranks.values().map(|r| r.events).sum()
    }
}

/// Incremental parse state for one NDJSON source feeding a
/// [`TraceFold`]. Keep one per worker stream / input file; drop it
/// when the source ends (the fold itself persists).
#[derive(Default)]
pub struct FoldStream {
    docs: StreamDocs,
}

impl FoldStream {
    pub fn new() -> FoldStream {
        FoldStream::default()
    }

    /// Push the next byte slice from this source into `fold`.
    pub fn feed(&mut self, fold: &mut TraceFold, bytes: &[u8]) -> Result<(), JsonError> {
        self.docs.feed(bytes, |doc| fold.add_doc(&doc))
    }

    /// Signal end of this source (rejects a truncated final line).
    pub fn finish(&mut self, fold: &mut TraceFold) -> Result<(), JsonError> {
        self.docs.finish(|doc| fold.add_doc(&doc))
    }

    /// High-water resident parse memory for this source — bounded by
    /// the largest line, asserted by tests.
    pub fn peak_resident_bytes(&self) -> usize {
        self.docs.peak_resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_a_stream_in_tiny_slices_with_bounded_memory() {
        // A synthetic multi-line report: meta + many events.
        let mut src = String::from(
            "{\"schema\":\"trace_meta_v1\",\"rank\":2,\"wall_anchor_ns\":1000}\n",
        );
        for i in 0..200 {
            src.push_str(&format!(
                "{{\"schema\":\"trace_event_v1\",\"kind\":\"chunk_send\",\"rank\":2,\
                 \"t_ns\":{},\"dur_ns\":10,\"peer\":0,\"ns\":2,\"epoch\":1,\"step\":{i},\
                 \"bytes\":4096,\"chunk\":{i}}}\n",
                i * 100
            ));
        }
        let mut fold = TraceFold::new();
        let mut stream = FoldStream::new();
        for chunk in src.as_bytes().chunks(7) {
            stream.feed(&mut fold, chunk).unwrap();
        }
        stream.finish(&mut fold).unwrap();
        let agg = &fold.ranks[&2];
        assert_eq!(agg.events, 200);
        assert_eq!(agg.wall_anchor_ns, 1000);
        let k = agg.kinds.get("chunk_send").unwrap();
        assert_eq!(k.count, 200);
        assert_eq!(k.total_bytes, 200 * 4096);
        assert_eq!(k.total_dur_ns, 2000);
        assert_eq!(agg.t_min_ns, 0);
        assert_eq!(agg.t_max_ns, 199 * 100 + 10);
        // Peak resident memory is one line's worth, not the stream's.
        assert!(
            stream.peak_resident_bytes() < 4096,
            "peak {} should be bounded by the largest line",
            stream.peak_resident_bytes()
        );
        assert!(src.len() > 20_000, "the stream itself is much larger");
    }

    #[test]
    fn coll_ops_fold_by_phase() {
        let mut fold = TraceFold::new();
        // step packs level|phase|round; phase 5 = reduce_scatter.
        let line = "{\"schema\":\"trace_event_v1\",\"kind\":\"coll_op\",\"rank\":0,\
                    \"t_ns\":5,\"dur_ns\":3,\"ns\":8,\"epoch\":1,\"step\":327680,\
                    \"bytes\":64,\"group\":4}\n";
        let mut stream = FoldStream::new();
        stream.feed(&mut fold, line.as_bytes()).unwrap();
        stream.finish(&mut fold).unwrap();
        let agg = &fold.ranks[&0];
        assert_eq!(agg.phases.get("reduce_scatter").unwrap().count, 1);
    }

    #[test]
    fn unknown_event_kinds_are_counted() {
        let mut fold = TraceFold::new();
        let mut stream = FoldStream::new();
        stream
            .feed(
                &mut fold,
                b"{\"schema\":\"trace_event_v1\",\"kind\":\"from_the_future\",\"rank\":0,\
                  \"t_ns\":1,\"dur_ns\":0}\n",
            )
            .unwrap();
        stream.finish(&mut fold).unwrap();
        assert_eq!(fold.unknown_kinds, 1);
        // The event still folds (forward compatibility), it's just
        // flagged.
        assert_eq!(fold.total_events(), 1);
    }

    #[test]
    fn hist_lines_fold_last_wins() {
        let mut fold = TraceFold::new();
        let mut stream = FoldStream::new();
        let early = "{\"schema\":\"trace_hist_v1\",\"rank\":1,\"hist\":\"pool_wait_ns\",\
                     \"count\":2,\"sum\":10,\"buckets\":[[3,2]]}\n";
        let late = "{\"schema\":\"trace_hist_v1\",\"rank\":1,\"hist\":\"pool_wait_ns\",\
                    \"count\":5,\"sum\":99,\"buckets\":[[3,4],[7,1]]}\n";
        stream.feed(&mut fold, early.as_bytes()).unwrap();
        stream.feed(&mut fold, late.as_bytes()).unwrap();
        stream.finish(&mut fold).unwrap();
        let h = fold.ranks[&1].hists.get("pool_wait_ns").unwrap();
        assert_eq!(h.count, 5, "cumulative totals: the latest line supersedes");
        assert_eq!(h.sum, 99);
        assert_eq!(fold.unknown_lines, 0);
    }

    #[test]
    fn unknown_schemas_are_counted_not_fatal() {
        let mut fold = TraceFold::new();
        let mut stream = FoldStream::new();
        stream.feed(&mut fold, b"{\"schema\":\"other\"}\n{\"x\":1}\n").unwrap();
        stream.finish(&mut fold).unwrap();
        assert_eq!(fold.lines, 2);
        assert_eq!(fold.unknown_lines, 2);
        assert_eq!(fold.total_events(), 0);
    }
}
