//! Fixed-size log2-bucketed latency histograms for the hot path.
//!
//! The span ring captures *individual* events; a bounded run can only
//! keep the newest 64Ki of them. Histograms keep the *distribution*
//! forever at O(1) memory: 64 power-of-two buckets of saturating
//! atomic counters, recorded with one CAS loop per sample — no
//! allocation, no locks, no loss on ring wrap. Three process-global
//! instruments cover the paths the analysis plane attributes time to:
//!
//! * [`HistKind::ChunkWait`] — receiver-side chunk arrival wait
//!   (datapath drain/recv stamps),
//! * [`HistKind::CollRound`] — collective round/span durations
//!   (fed centrally from [`super::record_span`]),
//! * [`HistKind::PoolWait`] — buffer-pool checkout latency.
//!
//! Histograms ride the telemetry wire as `trace_hist_v1` NDJSON lines
//! (see `docs/trace_schema.md`): cumulative totals emitted with every
//! [`super::emit::render_pending`] / `close_sink`, folded last-wins by
//! the leader. [`HistSnapshot`] is the plain-data mirror used for
//! merging, quantiles, and the wire format.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: bucket 0 holds zeros, bucket `i` (1..63) holds
/// values in `[2^(i-1), 2^i)`, bucket 63 holds everything above.
pub const BUCKETS: usize = 64;

/// The process-global histogram instruments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum HistKind {
    /// Receiver-side wait per datapath chunk: time from "started
    /// waiting" (drain pass, blocking recv) to the chunk landing, ns.
    ChunkWait = 0,
    /// One collective round/group-call duration, ns (every `coll_op`
    /// span feeds this).
    CollRound = 1,
    /// One buffer-pool checkout, ns (lock + free-list pop).
    PoolWait = 2,
}

/// Number of [`HistKind`] instruments.
pub const N_HISTS: usize = 3;

/// All kinds, for iteration.
pub const KINDS: [HistKind; N_HISTS] =
    [HistKind::ChunkWait, HistKind::CollRound, HistKind::PoolWait];

/// Wire name of a histogram (the `hist` field of `trace_hist_v1`).
pub fn hist_name(kind: HistKind) -> &'static str {
    match kind {
        HistKind::ChunkWait => "chunk_arrive_wait_ns",
        HistKind::CollRound => "coll_round_ns",
        HistKind::PoolWait => "pool_wait_ns",
    }
}

/// Parse a wire histogram name (reader side).
pub fn hist_from_name(name: &str) -> Option<HistKind> {
    Some(match name {
        "chunk_arrive_wait_ns" => HistKind::ChunkWait,
        "coll_round_ns" => HistKind::CollRound,
        "pool_wait_ns" => HistKind::PoolWait,
        _ => return None,
    })
}

/// Bucket index of a value: 0 for 0, else `bit_width(v)` clamped to
/// the last bucket — so bucket `i ≥ 1` covers `[2^(i-1), 2^i)`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Exclusive upper bound of bucket `i` (saturating for the last).
pub fn bucket_hi(i: usize) -> u64 {
    if i == 0 {
        1
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << i
    }
}

/// Saturating atomic add: the counter sticks at `u64::MAX` instead of
/// wrapping (a histogram must never under-report by overflow).
#[inline]
fn sat_add(a: &AtomicU64, v: u64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        if cur == u64::MAX {
            return;
        }
        let next = cur.saturating_add(v);
        match a.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// One fixed-size concurrent histogram: 64 saturating bucket counters
/// plus total count and sum. All fields are atomics — writers never
/// block, never allocate, and a snapshot can be taken while they run.
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            counts: [const { AtomicU64::new(0) }; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free, allocation-free, saturating.
    #[inline]
    pub fn record(&self, v: u64) {
        sat_add(&self.counts[bucket_of(v)], 1);
        sat_add(&self.count, 1);
        sat_add(&self.sum, v);
    }

    /// Total samples recorded (saturating).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current totals into a plain-data snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::new();
        for (i, c) in self.counts.iter().enumerate() {
            s.counts[i] = c.load(Ordering::Relaxed);
        }
        s.count = self.count.load(Ordering::Relaxed);
        s.sum = self.sum.load(Ordering::Relaxed);
        s
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Plain-data histogram totals: the merge/quantile/wire-format side of
/// [`Histogram`] (and the fold's per-rank aggregate).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot::new()
    }
}

impl HistSnapshot {
    pub fn new() -> HistSnapshot {
        HistSnapshot { counts: [0; BUCKETS], count: 0, sum: 0 }
    }

    /// Record one sample (the non-atomic twin of
    /// [`Histogram::record`], for folds and tests).
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] = self.counts[bucket_of(v)].saturating_add(1);
        self.count = self.count.saturating_add(1);
        self.sum = self.sum.saturating_add(v);
    }

    /// Merge another snapshot in (saturating): merge of disjoint
    /// splits equals the whole — the mergeability property tests pin.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum = self.sum.saturating_add(other.sum);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile `q ∈ [0, 1]`: the log2 buckets bound the
    /// answer to a factor of 2; within the winning bucket the value is
    /// interpolated linearly. Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen.saturating_add(c);
            if next >= target {
                let lo = bucket_lo(i) as f64;
                let hi = bucket_hi(i) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return (lo + (hi - lo) * frac) as u64;
            }
            seen = next;
        }
        bucket_hi(BUCKETS - 1)
    }

    /// Format as one `trace_hist_v1` NDJSON line (no newline).
    /// Buckets are sparse `[index, count]` pairs — most lines are a
    /// couple hundred bytes, never 64 zeros.
    pub fn wire_line(&self, rank: i64, kind: HistKind) -> String {
        use std::fmt::Write as _;
        let mut line = String::with_capacity(128);
        let _ = write!(
            line,
            "{{\"schema\":\"trace_hist_v1\",\"rank\":{rank},\"hist\":\"{}\",\
             \"count\":{},\"sum\":{},\"buckets\":[",
            hist_name(kind),
            self.count,
            self.sum
        );
        let mut first = true;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if !first {
                line.push(',');
            }
            first = false;
            let _ = write!(line, "[{i},{c}]");
        }
        line.push_str("]}");
        line
    }

    /// Parse the snapshot fields back out of a `trace_hist_v1`
    /// document (the `hist` name is the caller's job).
    pub fn from_doc(doc: &crate::json::Json) -> HistSnapshot {
        let mut s = HistSnapshot::new();
        s.count = doc.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        s.sum = doc.get("sum").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if let Some(items) = doc.get("buckets").and_then(|b| b.items()) {
            for pair in items {
                if let Some(p) = pair.items() {
                    if p.len() == 2 {
                        let i = p[0].as_f64().unwrap_or(0.0) as usize;
                        let c = p[1].as_f64().unwrap_or(0.0) as u64;
                        if i < BUCKETS {
                            s.counts[i] = s.counts[i].saturating_add(c);
                        }
                    }
                }
            }
        }
        s
    }
}

/// The process-global instruments, allocated statically (≈1.5 KiB).
static HISTS: [Histogram; N_HISTS] = [const { Histogram::new() }; N_HISTS];

/// One global instrument.
pub fn hist(kind: HistKind) -> &'static Histogram {
    &HISTS[kind as usize]
}

/// Record a sample into a global instrument; free when recording is
/// off (one relaxed load, like the event macros).
#[inline]
pub fn record(kind: HistKind, v: u64) {
    if super::COMPILED && super::enabled() {
        hist(kind).record(v);
    }
}

/// Record `now - start_ns` into a global instrument when `start_ns`
/// came from a live [`super::span_begin`] (0 means recording was off).
#[inline]
pub fn record_since(kind: HistKind, start_ns: u64) {
    if start_ns > 0 && super::COMPILED && super::enabled() {
        hist(kind).record(super::now_ns().saturating_sub(start_ns));
    }
}

/// Snapshots of every non-empty global instrument (emission side).
pub fn snapshots() -> Vec<(HistKind, HistSnapshot)> {
    KINDS
        .iter()
        .filter_map(|&k| {
            let s = hist(k).snapshot();
            if s.is_empty() {
                None
            } else {
                Some((k, s))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;

    #[test]
    fn buckets_partition_the_u64_range() {
        // Every value falls in exactly the bucket whose [lo, hi) range
        // contains it, and the ranges tile without gaps.
        for v in [0u64, 1, 2, 3, 7, 8, 1023, 1024, u64::MAX / 2, u64::MAX] {
            let i = bucket_of(v);
            assert!(v >= bucket_lo(i), "v {v} below bucket {i} lo");
            if i < BUCKETS - 1 {
                assert!(v < bucket_hi(i), "v {v} beyond bucket {i} hi");
            }
        }
        for i in 1..BUCKETS - 1 {
            assert_eq!(bucket_hi(i), bucket_lo(i + 1), "gap at bucket {i}");
        }
    }

    #[test]
    fn record_snapshot_quantile_roundtrip() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500500);
        let p50 = s.quantile(0.5);
        // Log2 buckets bound quantiles to a factor of 2.
        assert!((250..=1024).contains(&p50), "p50 {p50}");
        assert!(s.quantile(1.0) >= 512);
        assert_eq!(HistSnapshot::new().quantile(0.5), 0);
    }

    #[test]
    fn saturating_counters_never_wrap() {
        let mut s = HistSnapshot::new();
        s.count = u64::MAX - 1;
        s.counts[3] = u64::MAX;
        s.record(5);
        s.record(5);
        assert_eq!(s.count, u64::MAX);
        assert_eq!(s.counts[3], u64::MAX);
        let other = s.clone();
        s.merge(&other);
        assert_eq!(s.count, u64::MAX, "merge must saturate too");
    }

    #[test]
    fn wire_line_roundtrips_through_the_parser() {
        let mut s = HistSnapshot::new();
        for v in [0u64, 3, 3, 900, 70_000] {
            s.record(v);
        }
        let line = s.wire_line(2, HistKind::ChunkWait);
        let doc = Json::parse(&line).expect("hist line parses");
        assert_eq!(doc.get("schema").unwrap().as_str(), Some("trace_hist_v1"));
        assert_eq!(doc.get("hist").unwrap().as_str(), Some("chunk_arrive_wait_ns"));
        assert_eq!(doc.get("rank").unwrap().as_usize(), Some(2));
        let back = HistSnapshot::from_doc(&doc);
        assert_eq!(back, s);
    }

    #[test]
    fn hist_names_roundtrip() {
        for k in KINDS {
            assert_eq!(hist_from_name(hist_name(k)), Some(k));
        }
        assert_eq!(hist_from_name("nope"), None);
    }
}
