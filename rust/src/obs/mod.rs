//! Runtime telemetry plane: per-rank span tracing, counters, and a
//! leveled logging facade.
//!
//! The paper's fleet-wide bandwidth claims rest on continuously
//! measured per-rank telemetry folded into one view; this module is
//! that layer for the repro. Design constraints, in order:
//!
//! 1. **Zero cost when off.** `COMPILED` is a `const` derived from the
//!    `obs-off` feature; every recording macro tests it first, so with
//!    the feature enabled the instrumentation folds to nothing. At
//!    runtime a second (`AtomicBool`) gate keeps the default-build
//!    cost to one relaxed load per site.
//! 2. **Never allocate on the hot path.** [`Recorder`] is a bounded
//!    ring of pre-allocated atomic slots written seqlock-style: a
//!    ticket from `fetch_add`, odd/even sequence stamps around the
//!    field stores. Writers never block, never allocate, and overwrite
//!    the oldest events when the ring wraps (the drop count is kept).
//! 3. **Correlate across ranks.** Every event carries the recording
//!    rank, a monotonic nanosecond timestamp against a process-wide
//!    anchor, and the existing bit-field message tag
//!    ([`crate::comm::tags`]), so per-rank NDJSON streams merge into
//!    one coherent timeline (`repro trace-report`).
//!
//! Emission ([`emit`]), leader-side folding ([`fold`]) and reporting
//! ([`report`]) live in submodules; recording stays here so the hot
//! layers only pull in this file's symbols.

pub mod analyze;
pub mod causal;
pub mod emit;
pub mod fold;
pub mod hist;
pub mod report;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// `false` when built with `--features obs-off`: every recording
/// macro's body is behind `if COMPILED { .. }` and compiles away.
pub const COMPILED: bool = !cfg!(feature = "obs-off");

/// Runtime gate (the `--trace` / `--metrics-interval` switch).
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is event recording live right now? One relaxed load; recording
/// sites call this through the macros, never directly.
#[inline]
pub fn enabled() -> bool {
    COMPILED && ENABLED.load(Ordering::Relaxed)
}

/// Turn event recording on or off. With `obs-off` compiled this is a
/// no-op and [`enabled`] stays `false` forever — the const gate wins.
pub fn set_enabled(on: bool) {
    if COMPILED {
        ENABLED.store(on, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

struct Anchor {
    start: Instant,
    wall_ns: u64,
}

fn anchor() -> &'static Anchor {
    static ANCHOR: OnceLock<Anchor> = OnceLock::new();
    ANCHOR.get_or_init(|| Anchor {
        start: Instant::now(),
        wall_ns: SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0),
    })
}

/// Monotonic nanoseconds since the process's trace anchor.
#[inline]
pub fn now_ns() -> u64 {
    anchor().start.elapsed().as_nanos() as u64
}

/// Wall-clock nanoseconds (UNIX epoch) at the trace anchor — lets a
/// report align streams from different processes.
pub fn wall_anchor_ns() -> u64 {
    anchor().wall_ns
}

/// Start a span: the current monotonic time if recording is live,
/// else 0 (callers pass it straight back to [`obs_span!`], which
/// ignores it when recording is off).
#[inline]
pub fn span_begin() -> u64 {
    if enabled() {
        now_ns()
    } else {
        0
    }
}

// ---------------------------------------------------------------------------
// Rank attribution
// ---------------------------------------------------------------------------

const RANK_UNSET: u64 = u64::MAX;

/// Process-wide rank (one process per PID in spawned deployments).
static PROCESS_RANK: AtomicU64 = AtomicU64::new(RANK_UNSET);

thread_local! {
    /// Per-thread override for in-process SPMD (benches and tests run
    /// many ranks as threads of one process).
    static THREAD_RANK: std::cell::Cell<u64> = const { std::cell::Cell::new(RANK_UNSET) };
}

/// Set the process-wide rank (spawned workers call this once).
pub fn set_rank(rank: usize) {
    PROCESS_RANK.store(rank as u64, Ordering::Relaxed);
}

/// Override the rank for the calling thread (in-process SPMD).
pub fn set_thread_rank(rank: usize) {
    THREAD_RANK.with(|r| r.set(rank as u64));
}

/// Clear the calling thread's rank override.
pub fn clear_thread_rank() {
    THREAD_RANK.with(|r| r.set(RANK_UNSET));
}

/// The rank events on this thread are attributed to: the thread
/// override if set, else the process rank, else `None`.
pub fn current_rank() -> Option<u64> {
    let t = THREAD_RANK.with(|r| r.get());
    if t != RANK_UNSET {
        return Some(t);
    }
    let p = PROCESS_RANK.load(Ordering::Relaxed);
    if p != RANK_UNSET {
        Some(p)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// Typed span/counter events. The discriminant is the wire `kind`
/// byte; names and per-kind payload field names live in
/// [`kind_name`] / [`field_names`] so the NDJSON stays
/// self-describing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Remap plan construction (cache miss) — `a` = global elements.
    RemapPlan = 1,
    /// One whole remap execution — `a` = payload bytes sent, `b` =
    /// communicating peers.
    RemapExec = 2,
    /// One datapath chunk handed to the transport — `a` = wire bytes
    /// (frame included on chunk 0), `b` = chunk index.
    ChunkSend = 3,
    /// One datapath chunk arrival (drain or blocking recv) — `a` =
    /// wire bytes, `b` = chunk index.
    ChunkArrive = 4,
    /// One collective group call — `a` = payload bytes, `b` = group
    /// size; the tag's step field carries `level|phase|round`.
    CollOp = 5,
    /// One overlapped scatter window unpacked on arrival — `a` =
    /// window bytes, `b` = destination offset.
    ScatterWindow = 6,
    /// Buffer-pool checkout that missed the free list — `a` =
    /// requested capacity.
    PoolMiss = 7,
    /// Periodic counter sample — tag field is the metric id
    /// ([`metric_name`]), `a` = value.
    Metric = 8,
    /// Free-form instant marker.
    Mark = 9,
    /// A live rank missed one heartbeat round — `peer` = the silent
    /// rank, `a` = consecutive misses so far.
    HeartbeatMiss = 10,
    /// A rank crossed the miss threshold and was declared dead —
    /// `peer` = the dead rank, `a` = misses at the verdict.
    RankDead = 11,
    /// One elastic re-deal (P → survivors remap) — `a` = global
    /// elements moved, `b` = survivor count.
    Redeal = 12,
    /// One checkpoint shard written — `a` = shard bytes, `b` = epoch.
    Checkpoint = 13,
    /// One checkpoint shard restored — `a` = shard bytes, `b` =
    /// epoch resumed from.
    Restore = 14,
}

impl EventKind {
    /// Decode a wire kind byte.
    pub fn from_u8(k: u8) -> Option<EventKind> {
        Some(match k {
            1 => EventKind::RemapPlan,
            2 => EventKind::RemapExec,
            3 => EventKind::ChunkSend,
            4 => EventKind::ChunkArrive,
            5 => EventKind::CollOp,
            6 => EventKind::ScatterWindow,
            7 => EventKind::PoolMiss,
            8 => EventKind::Metric,
            9 => EventKind::Mark,
            10 => EventKind::HeartbeatMiss,
            11 => EventKind::RankDead,
            12 => EventKind::Redeal,
            13 => EventKind::Checkpoint,
            14 => EventKind::Restore,
            _ => return None,
        })
    }
}

/// Wire name for a kind (the NDJSON `kind` field).
pub fn kind_name(kind: EventKind) -> &'static str {
    match kind {
        EventKind::RemapPlan => "remap_plan",
        EventKind::RemapExec => "remap_exec",
        EventKind::ChunkSend => "chunk_send",
        EventKind::ChunkArrive => "chunk_arrive",
        EventKind::CollOp => "coll_op",
        EventKind::ScatterWindow => "scatter_window",
        EventKind::PoolMiss => "pool_miss",
        EventKind::Metric => "metric",
        EventKind::Mark => "mark",
        EventKind::HeartbeatMiss => "fault_hb_miss",
        EventKind::RankDead => "fault_rank_dead",
        EventKind::Redeal => "fault_redeal",
        EventKind::Checkpoint => "fault_ckpt",
        EventKind::Restore => "fault_restore",
    }
}

/// Parse a wire kind name back to the enum (trace-report input side).
pub fn kind_from_name(name: &str) -> Option<EventKind> {
    Some(match name {
        "remap_plan" => EventKind::RemapPlan,
        "remap_exec" => EventKind::RemapExec,
        "chunk_send" => EventKind::ChunkSend,
        "chunk_arrive" => EventKind::ChunkArrive,
        "coll_op" => EventKind::CollOp,
        "scatter_window" => EventKind::ScatterWindow,
        "pool_miss" => EventKind::PoolMiss,
        "metric" => EventKind::Metric,
        "mark" => EventKind::Mark,
        "fault_hb_miss" => EventKind::HeartbeatMiss,
        "fault_rank_dead" => EventKind::RankDead,
        "fault_redeal" => EventKind::Redeal,
        "fault_ckpt" => EventKind::Checkpoint,
        "fault_restore" => EventKind::Restore,
        _ => return None,
    })
}

/// Self-describing NDJSON field names for the `a` / `b` payloads.
pub fn field_names(kind: EventKind) -> (&'static str, &'static str) {
    match kind {
        EventKind::RemapPlan => ("elems", "groups"),
        EventKind::RemapExec => ("bytes", "peers"),
        EventKind::ChunkSend | EventKind::ChunkArrive => ("bytes", "chunk"),
        EventKind::CollOp => ("bytes", "group"),
        EventKind::ScatterWindow => ("bytes", "offset"),
        EventKind::PoolMiss => ("capacity", "b"),
        EventKind::Metric => ("value", "b"),
        EventKind::Mark => ("a", "b"),
        EventKind::HeartbeatMiss | EventKind::RankDead => ("missed", "b"),
        EventKind::Redeal => ("elems", "survivors"),
        EventKind::Checkpoint | EventKind::Restore => ("bytes", "epoch"),
    }
}

/// Metric ids for [`EventKind::Metric`] samples (stored in the tag
/// field so `a` stays the value).
pub mod metric {
    pub const POOL_CHECKOUTS: u64 = 0;
    pub const POOL_HITS: u64 = 1;
    pub const DP_MSGS_SENT: u64 = 2;
    pub const DP_BYTES_SENT: u64 = 3;
    pub const DP_MSGS_RECV: u64 = 4;
    pub const DP_BYTES_RECV: u64 = 5;
}

/// Wire name of a metric id.
pub fn metric_name(id: u64) -> &'static str {
    match id {
        metric::POOL_CHECKOUTS => "pool_checkouts",
        metric::POOL_HITS => "pool_hits",
        metric::DP_MSGS_SENT => "datapath_msgs_sent",
        metric::DP_BYTES_SENT => "datapath_bytes_sent",
        metric::DP_MSGS_RECV => "datapath_msgs_recv",
        metric::DP_BYTES_RECV => "datapath_bytes_recv",
        _ => "unknown",
    }
}

/// One drained trace event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Monotonic nanoseconds since the process anchor.
    pub t_ns: u64,
    /// Span duration (0 for instant events).
    pub dur_ns: u64,
    pub kind: EventKind,
    /// Recording rank ([`current_rank`] at the call site; `u32::MAX`
    /// when unattributed).
    pub rank: u32,
    /// Peer rank for point-to-point events (`u32::MAX` when N/A).
    pub peer: u32,
    /// The bit-field message tag (see [`crate::comm::tags`]); 0 when
    /// the event has no message stream.
    pub tag: u64,
    /// Kind-specific payload (see [`field_names`]).
    pub a: u64,
    /// Kind-specific payload (see [`field_names`]).
    pub b: u64,
}

/// Sentinel for "no peer" in [`Event::peer`] / recording calls.
pub const NO_PEER: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// Recorder: bounded seqlock ring
// ---------------------------------------------------------------------------

/// One ring slot: a sequence word plus six payload words. The writer
/// stamps `seq = 2·ticket+1` (torn), stores the payload, then
/// `seq = 2·ticket+2` (complete); the drain re-checks `seq` after
/// reading so a concurrently overwritten slot is dropped, never
/// misread.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; 6],
}

impl Slot {
    fn new() -> Slot {
        Slot { seq: AtomicU64::new(0), words: [const { AtomicU64::new(0) }; 6] }
    }
}

/// Lock-free bounded ring of trace events: fixed capacity, allocated
/// once, overwrite-oldest, counted drops. One process-global instance
/// ([`recorder`]) serves every rank in the process; events carry
/// their recording rank so in-process SPMD stays attributable.
pub struct Recorder {
    slots: Vec<Slot>,
    /// Next ticket (total events ever recorded).
    head: AtomicU64,
    /// Next ticket to drain.
    drained: AtomicU64,
    /// Events lost to wrap-around or torn reads.
    dropped: AtomicU64,
}

/// Default ring capacity: 64Ki events ≈ 4 MiB resident.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

impl Recorder {
    /// A ring with `capacity` slots (rounded up to at least 8).
    pub fn with_capacity(capacity: usize) -> Recorder {
        let cap = capacity.max(8);
        Recorder {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record one event. Never blocks, never allocates; wraps over the
    /// oldest undrained event when the ring is full.
    pub fn record(&self, ev: Event) {
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        slot.seq.store(2 * ticket + 1, Ordering::Release);
        let meta = ev.kind as u64 | ((ev.rank as u64) << 8) | ((ev.peer as u64) << 32);
        slot.words[0].store(ev.t_ns, Ordering::Relaxed);
        slot.words[1].store(ev.dur_ns, Ordering::Relaxed);
        slot.words[2].store(meta, Ordering::Relaxed);
        slot.words[3].store(ev.tag, Ordering::Relaxed);
        slot.words[4].store(ev.a, Ordering::Relaxed);
        slot.words[5].store(ev.b, Ordering::Relaxed);
        slot.seq.store(2 * ticket + 2, Ordering::Release);
    }

    /// Drain every completed event since the last drain, oldest first,
    /// into `f`. Events overwritten before they could be read are
    /// counted in [`Recorder::dropped`]. Returns how many events were
    /// delivered.
    ///
    /// Writers may race a drain freely; **drains** are intended to be
    /// one at a time (the sink flusher, the worker's report step) —
    /// concurrent drains contend on the cursor and may then deliver an
    /// event twice or skip it. Per-process deployments have a single
    /// drainer by construction.
    pub fn drain(&self, mut f: impl FnMut(Event)) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let mut next = self.drained.load(Ordering::Acquire);
        if head > next + cap {
            // The ring lapped the drain cursor: those events are gone.
            let lost = head - cap - next;
            self.dropped.fetch_add(lost, Ordering::Relaxed);
            next = head - cap;
        }
        let mut delivered = 0;
        while next < head {
            let slot = &self.slots[(next % cap) as usize];
            let before = slot.seq.load(Ordering::Acquire);
            if before != 2 * next + 2 {
                // Torn or already overwritten by a racing writer.
                self.dropped.fetch_add(1, Ordering::Relaxed);
                next += 1;
                continue;
            }
            let t_ns = slot.words[0].load(Ordering::Relaxed);
            let dur_ns = slot.words[1].load(Ordering::Relaxed);
            let meta = slot.words[2].load(Ordering::Relaxed);
            let tag = slot.words[3].load(Ordering::Relaxed);
            let a = slot.words[4].load(Ordering::Relaxed);
            let b = slot.words[5].load(Ordering::Relaxed);
            let after = slot.seq.load(Ordering::Acquire);
            if after != before {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                next += 1;
                continue;
            }
            if let Some(kind) = EventKind::from_u8((meta & 0xFF) as u8) {
                f(Event {
                    t_ns,
                    dur_ns,
                    kind,
                    rank: ((meta >> 8) & 0x00FF_FFFF) as u32,
                    peer: (meta >> 32) as u32,
                    tag,
                    a,
                    b,
                });
                delivered += 1;
            }
            next += 1;
        }
        self.drained.store(next, Ordering::Release);
        delivered
    }

    /// Events lost to wrap-around or torn concurrent writes.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total events ever recorded (including dropped ones).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }
}

/// The process-global recorder (created on first touch).
pub fn recorder() -> &'static Recorder {
    static RECORDER: OnceLock<Recorder> = OnceLock::new();
    RECORDER.get_or_init(|| Recorder::with_capacity(DEFAULT_CAPACITY))
}

/// Record an instant event into the global ring, stamping the current
/// time and rank. Recording sites go through the macros, which check
/// the gates first.
#[inline]
pub fn record(kind: EventKind, tag: u64, peer: u32, a: u64, b: u64) {
    record_span(kind, 0, tag, peer, a, b);
}

/// Record a span that began at monotonic `start_ns` ([`span_begin`]).
#[inline]
pub fn record_span(kind: EventKind, start_ns: u64, tag: u64, peer: u32, a: u64, b: u64) {
    let now = now_ns();
    let rank = current_rank().map(|r| r as u32).unwrap_or(u32::MAX);
    if kind == EventKind::CollOp && start_ns > 0 {
        // Every collective round/group-call span also feeds the O(1)
        // round-time histogram, which survives ring wrap.
        hist::hist(hist::HistKind::CollRound).record(now.saturating_sub(start_ns));
    }
    recorder().record(Event {
        t_ns: if start_ns > 0 { start_ns } else { now },
        dur_ns: if start_ns > 0 { now.saturating_sub(start_ns) } else { 0 },
        kind,
        rank,
        peer,
        tag,
        a,
        b,
    });
}

/// Record an instant trace event; compiles to nothing under `obs-off`
/// and costs one relaxed load when tracing is not enabled.
///
/// ```ignore
/// obs_event!(EventKind::PoolMiss, tag: 0, peer: obs::NO_PEER, a: cap as u64, b: 0);
/// ```
#[macro_export]
macro_rules! obs_event {
    ($kind:expr, tag: $tag:expr, peer: $peer:expr, a: $a:expr, b: $b:expr) => {
        if $crate::obs::COMPILED && $crate::obs::enabled() {
            $crate::obs::record($kind, $tag, $peer, $a, $b);
        }
    };
}

/// Close a span opened with [`span_begin`]; same gating as
/// [`obs_event!`]. A `start` of 0 (recording was off at open) records
/// an instant at the current time instead of a bogus duration.
#[macro_export]
macro_rules! obs_span {
    ($kind:expr, $start:expr, tag: $tag:expr, peer: $peer:expr, a: $a:expr, b: $b:expr) => {
        if $crate::obs::COMPILED && $crate::obs::enabled() {
            $crate::obs::record_span($kind, $start, $tag, $peer, $a, $b);
        }
    };
}

// ---------------------------------------------------------------------------
// Leveled logging facade
// ---------------------------------------------------------------------------

/// Log severity, most severe first. The `DISTARRAY_LOG` env var sets
/// the threshold (`off`, `error`, `warn`, `info`, `debug`, `trace`);
/// default `info`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

fn log_threshold() -> u8 {
    static THRESHOLD: OnceLock<u8> = OnceLock::new();
    *THRESHOLD.get_or_init(|| match std::env::var("DISTARRAY_LOG").as_deref() {
        Ok("off") | Ok("none") => 0,
        Ok("error") => Level::Error as u8,
        Ok("warn") => Level::Warn as u8,
        Ok("debug") => Level::Debug as u8,
        Ok("trace") => Level::Trace as u8,
        // `info`, unset, or unrecognized: the default threshold.
        _ => Level::Info as u8,
    })
}

/// Would a message at `level` be emitted?
#[inline]
pub fn log_enabled(level: Level) -> bool {
    level as u8 <= log_threshold()
}

/// Emit one rank-prefixed line to stderr:
/// `[distarray r3] WARN message`. Call through [`log!`].
pub fn log_line(level: Level, args: std::fmt::Arguments<'_>) {
    use std::io::Write;
    let stderr = std::io::stderr();
    let mut out = stderr.lock();
    match current_rank() {
        Some(r) => {
            let _ = writeln!(out, "[distarray r{r}] {} {args}", level.label());
        }
        None => {
            let _ = writeln!(out, "[distarray] {} {args}", level.label());
        }
    }
}

/// Leveled, rank-prefixed diagnostic logging:
/// `log!(Warn, "drain stalled on pid {p}")`. Filtered by the
/// `DISTARRAY_LOG` env var (default `info`); lines go to stderr as
/// `[distarray r<rank>] LEVEL message`, so multi-worker output is
/// attributable and greppable.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {
        if $crate::obs::log_enabled($crate::obs::Level::$lvl) {
            $crate::obs::log_line($crate::obs::Level::$lvl, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_gate_tracks_the_feature() {
        // The whole zero-cost claim: COMPILED is a const mirror of the
        // obs-off feature, and with it off set_enabled can never stick.
        assert_eq!(COMPILED, !cfg!(feature = "obs-off"));
        if !COMPILED {
            set_enabled(true);
            assert!(!enabled(), "obs-off build must never enable recording");
        }
    }

    #[test]
    fn ring_records_and_drains_in_order() {
        let r = Recorder::with_capacity(16);
        for i in 0..10u64 {
            r.record(Event {
                t_ns: i,
                dur_ns: 0,
                kind: EventKind::Mark,
                rank: 1,
                peer: NO_PEER,
                tag: i,
                a: i * 2,
                b: 0,
            });
        }
        let mut seen = Vec::new();
        let n = r.drain(|ev| seen.push(ev.tag));
        assert_eq!(n, 10);
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert_eq!(r.dropped(), 0);
        // Nothing left after a drain.
        assert_eq!(r.drain(|_| {}), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let r = Recorder::with_capacity(8);
        for i in 0..20u64 {
            r.record(Event {
                t_ns: i,
                dur_ns: 0,
                kind: EventKind::Mark,
                rank: 0,
                peer: NO_PEER,
                tag: i,
                a: 0,
                b: 0,
            });
        }
        let mut seen = Vec::new();
        r.drain(|ev| seen.push(ev.tag));
        // Only the newest `cap` events survive; the rest are counted.
        assert_eq!(seen, (12..20).collect::<Vec<_>>());
        assert_eq!(r.dropped(), 12);
    }

    #[test]
    fn concurrent_writers_never_corrupt_a_drain() {
        use std::sync::Arc;
        let r = Arc::new(Recorder::with_capacity(64));
        let mut hs = Vec::new();
        for w in 0..4u64 {
            let r = Arc::clone(&r);
            hs.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    r.record(Event {
                        t_ns: i,
                        dur_ns: 0,
                        kind: EventKind::Mark,
                        rank: w as u32,
                        peer: NO_PEER,
                        tag: w << 32 | i,
                        a: i,
                        b: w,
                    });
                }
            }));
        }
        // Drain concurrently with the writers: every delivered event
        // must be internally consistent (tag fields match).
        let mut total = 0usize;
        for _ in 0..50 {
            total += r.drain(|ev| {
                assert_eq!(ev.tag & 0xFFFF_FFFF, ev.a);
                assert_eq!(ev.tag >> 32, ev.b);
            });
        }
        for h in hs {
            h.join().unwrap();
        }
        total += r.drain(|ev| {
            assert_eq!(ev.tag & 0xFFFF_FFFF, ev.a);
        });
        assert_eq!(total as u64 + r.dropped(), r.recorded());
    }

    #[test]
    fn thread_rank_overrides_process_rank() {
        std::thread::spawn(|| {
            assert_eq!(current_rank(), None.or(current_rank()));
            set_thread_rank(7);
            assert_eq!(current_rank(), Some(7));
            clear_thread_rank();
        })
        .join()
        .unwrap();
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in 1..=14u8 {
            let kind = EventKind::from_u8(k).unwrap();
            assert_eq!(kind_from_name(kind_name(kind)), Some(kind));
        }
        assert_eq!(EventKind::from_u8(0), None);
        assert_eq!(EventKind::from_u8(15), None);
        assert_eq!(kind_from_name("nope"), None);
    }
}
