//! `repro trace-report`: merge per-rank NDJSON traces into a
//! fleet-wide summary, validate them line by line, and export a
//! Chrome `trace_event` document for chrome://tracing.
//!
//! Every pass over the input is streaming — files are read in fixed
//! chunks through the incremental parser, so arbitrarily large traces
//! fold in memory bounded by the largest line.

use super::fold::{phase_name, FoldStream, TraceFold};
use super::{hist, kind_from_name};
use crate::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read, Write};

const READ_CHUNK: usize = 64 * 1024;

/// Stream every file into one fleet-wide [`TraceFold`].
pub fn fold_files(paths: &[String]) -> Result<TraceFold, String> {
    let mut fold = TraceFold::new();
    for path in paths {
        let mut f = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
        let mut stream = FoldStream::new();
        let mut buf = vec![0u8; READ_CHUNK];
        loop {
            let n = f.read(&mut buf).map_err(|e| format!("{path}: {e}"))?;
            if n == 0 {
                break;
            }
            stream.feed(&mut fold, &buf[..n]).map_err(|e| format!("{path}: {e}"))?;
        }
        stream.finish(&mut fold).map_err(|e| format!("{path}: {e}"))?;
    }
    Ok(fold)
}

/// Render the per-rank / per-kind / per-phase summary table.
pub fn render_summary(fold: &TraceFold) -> String {
    let mut out = String::new();
    let dropped: u64 = fold.ranks.values().map(|r| r.dropped).sum();
    let _ = writeln!(
        out,
        "trace-report: {} rank(s), {} event(s), {} line(s), {} dropped",
        fold.ranks.len(),
        fold.total_events(),
        fold.lines,
        dropped
    );
    if fold.unknown_kinds > 0 {
        let _ = writeln!(
            out,
            "unknown_kinds: {} event(s) carry a kind this build doesn't know (schema drift)",
            fold.unknown_kinds
        );
    }
    let _ = writeln!(out, "\n{:>6} {:>10} {:>9} {:>12}", "rank", "events", "dropped", "wall_s");
    for (rank, agg) in &fold.ranks {
        let _ = writeln!(
            out,
            "{:>6} {:>10} {:>9} {:>12.6}",
            rank,
            agg.events,
            agg.dropped,
            agg.wall_seconds()
        );
    }
    // Kind totals across ranks.
    let mut kinds: std::collections::BTreeMap<&str, super::fold::KindAgg> = Default::default();
    let mut phases: std::collections::BTreeMap<&str, super::fold::KindAgg> = Default::default();
    for agg in fold.ranks.values() {
        for (k, v) in &agg.kinds {
            let e = kinds.entry(k.as_str()).or_default();
            e.count += v.count;
            e.total_dur_ns += v.total_dur_ns;
            e.total_bytes += v.total_bytes;
        }
        for (p, v) in &agg.phases {
            let e = phases.entry(p).or_default();
            e.count += v.count;
            e.total_dur_ns += v.total_dur_ns;
            e.total_bytes += v.total_bytes;
        }
    }
    let _ = writeln!(
        out,
        "\n{:<16} {:>10} {:>12} {:>12} {:>10}",
        "kind", "count", "total_ms", "MB", "GB/s"
    );
    for (k, v) in &kinds {
        let _ = writeln!(
            out,
            "{:<16} {:>10} {:>12.3} {:>12.3} {:>10.3}",
            k,
            v.count,
            v.total_dur_ns as f64 / 1e6,
            v.total_bytes as f64 / 1e6,
            v.gb_per_sec()
        );
    }
    if !phases.is_empty() {
        let _ = writeln!(
            out,
            "\n{:<16} {:>10} {:>12} {:>12}",
            "coll phase", "count", "total_ms", "MB"
        );
        for (p, v) in &phases {
            let _ = writeln!(
                out,
                "{:<16} {:>10} {:>12.3} {:>12.3}",
                p,
                v.count,
                v.total_dur_ns as f64 / 1e6,
                v.total_bytes as f64 / 1e6
            );
        }
    }
    // Runtime histograms, merged across ranks.
    let mut hists: BTreeMap<&str, super::hist::HistSnapshot> = BTreeMap::new();
    for agg in fold.ranks.values() {
        for (name, snap) in &agg.hists {
            hists
                .entry(name.as_str())
                .or_insert_with(super::hist::HistSnapshot::new)
                .merge(snap);
        }
    }
    if !hists.is_empty() {
        let _ = writeln!(
            out,
            "\n{:<20} {:>10} {:>14} {:>14} {:>14}",
            "hist", "count", "p50_ns", "p95_ns", "p99_ns"
        );
        for (name, h) in &hists {
            let _ = writeln!(
                out,
                "{:<20} {:>10} {:>14} {:>14} {:>14}",
                name,
                h.count,
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99)
            );
        }
    }
    out
}

/// What `--check` found: counts plus non-fatal warnings (timestamp
/// regressions, anchor skew) that would otherwise surface as silently
/// garbled merges.
#[derive(Debug, Default)]
pub struct CheckReport {
    pub lines: usize,
    pub events: usize,
    /// `trace_hist_v1` lines seen.
    pub hists: usize,
    pub warnings: Vec<String>,
}

/// Anchor offsets larger than this are suspicious: processes of one
/// run start within seconds of each other, so a minute-scale gap
/// means a stale file or a badly skewed wall clock got mixed in.
const ANCHOR_SKEW_WARN_NS: u64 = 60_000_000_000;

/// Strictly validate trace files line by line. Every line must parse
/// as JSON and carry a known schema; event lines must name a known
/// kind; hist lines a known histogram. Per-(file, rank) timestamp
/// monotonicity and cross-rank anchor skew are checked too, but those
/// produce [`CheckReport::warnings`] naming the offending rank rather
/// than errors — the files are still mergeable, just suspect.
pub fn check_files(paths: &[String]) -> Result<CheckReport, String> {
    let mut report = CheckReport::default();
    // Opening wall anchor per rank, across all files.
    let mut anchors: BTreeMap<i64, u64> = BTreeMap::new();
    for path in paths {
        let mut f = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
        let mut buf = vec![0u8; READ_CHUNK];
        let mut line = Vec::new();
        let mut lineno = 0usize;
        // Last t_ns per rank in THIS file; ranks already flagged.
        let mut last_t: BTreeMap<i64, u64> = BTreeMap::new();
        let mut flagged: std::collections::BTreeSet<i64> = Default::default();
        let mut check_line = |line: &[u8],
                              lineno: usize,
                              report: &mut CheckReport|
         -> Result<bool, String> {
            let text = std::str::from_utf8(line)
                .map_err(|_| format!("{path}:{lineno}: not utf-8"))?;
            if text.trim().is_empty() {
                return Ok(false);
            }
            let doc = Json::parse(text.trim())
                .map_err(|e| format!("{path}:{lineno}: {e}"))?;
            let rank =
                doc.get("rank").and_then(|r| r.as_f64()).map(|r| r as i64).unwrap_or(-1);
            match doc.get("schema").and_then(|s| s.as_str()) {
                Some("trace_meta_v1") => {
                    if let Some(w) = doc.get("wall_anchor_ns").and_then(|v| v.as_f64()) {
                        anchors.entry(rank).or_insert(w as u64);
                    }
                    Ok(false)
                }
                Some("trace_event_v1") => {
                    let kind = doc
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .ok_or_else(|| format!("{path}:{lineno}: event without kind"))?;
                    kind_from_name(kind)
                        .ok_or_else(|| format!("{path}:{lineno}: unknown kind '{kind}'"))?;
                    for field in ["rank", "t_ns", "dur_ns"] {
                        if doc.get(field).and_then(|v| v.as_f64()).is_none() {
                            return Err(format!("{path}:{lineno}: event missing {field}"));
                        }
                    }
                    let t_ns =
                        doc.get("t_ns").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                    // The ring drains in record order, so a backward
                    // step within one rank's stream means a garbled
                    // merge (or a clock that went backward).
                    let last = last_t.entry(rank).or_insert(t_ns);
                    if t_ns < *last && flagged.insert(rank) {
                        report.warnings.push(format!(
                            "{path}:{lineno}: rank {rank} timestamps regress \
                             ({t_ns} < {last}) — stream is not monotonic"
                        ));
                    }
                    *last = (*last).max(t_ns);
                    Ok(true)
                }
                Some("trace_hist_v1") => {
                    let name = doc
                        .get("hist")
                        .and_then(|h| h.as_str())
                        .ok_or_else(|| format!("{path}:{lineno}: hist line without name"))?;
                    hist::hist_from_name(name).ok_or_else(|| {
                        format!("{path}:{lineno}: unknown histogram '{name}'")
                    })?;
                    for field in ["rank", "count", "sum"] {
                        if doc.get(field).and_then(|v| v.as_f64()).is_none() {
                            return Err(format!("{path}:{lineno}: hist missing {field}"));
                        }
                    }
                    report.hists += 1;
                    Ok(false)
                }
                Some(s) => Err(format!("{path}:{lineno}: unknown schema '{s}'")),
                None => Err(format!("{path}:{lineno}: line without schema")),
            }
        };
        loop {
            let n = f.read(&mut buf).map_err(|e| format!("{path}: {e}"))?;
            if n == 0 {
                break;
            }
            for &b in &buf[..n] {
                if b == b'\n' {
                    lineno += 1;
                    if check_line(&line, lineno, &mut report)? {
                        report.events += 1;
                    }
                    if !line.is_empty() {
                        report.lines += 1;
                    }
                    line.clear();
                } else {
                    line.push(b);
                }
            }
        }
        if !line.is_empty() {
            lineno += 1;
            if check_line(&line, lineno, &mut report)? {
                report.events += 1;
            }
            report.lines += 1;
        }
    }
    // Cross-rank anchor skew: every rank of one run starts within
    // seconds; a minute-plus outlier is a stale or foreign file.
    if anchors.len() > 1 {
        let median = {
            let mut v: Vec<u64> = anchors.values().copied().collect();
            v.sort_unstable();
            v[v.len() / 2]
        };
        for (&rank, &a) in &anchors {
            if a.abs_diff(median) > ANCHOR_SKEW_WARN_NS {
                report.warnings.push(format!(
                    "rank {rank} wall anchor is {:.1}s from the median — stale or \
                     foreign trace file?",
                    a.abs_diff(median) as f64 / 1e9
                ));
            }
        }
    }
    Ok(report)
}

/// Export the traces as one Chrome `trace_event` JSON document
/// (chrome://tracing / Perfetto "load trace"). Spans become `"ph":"X"`
/// complete events, instants become `"ph":"i"`; `pid`/`tid` carry the
/// rank and timestamps are aligned across processes via each stream's
/// wall anchor.
pub fn write_chrome(paths: &[String], out_path: &str) -> Result<(), String> {
    let out = std::fs::File::create(out_path).map_err(|e| format!("{out_path}: {e}"))?;
    let mut w = std::io::BufWriter::new(out);
    write!(w, "{{\"displayTimeUnit\":\"ns\",\"traceEvents\":[").map_err(|e| e.to_string())?;
    let mut first = true;
    for path in paths {
        let mut f = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
        let mut docs = crate::json::StreamDocs::new();
        let mut buf = vec![0u8; READ_CHUNK];
        // The wall anchor arrives in the stream's first (meta) line;
        // events are shifted by it so ranks share one timeline.
        let mut anchor_ns = 0f64;
        let mut err: Option<String> = None;
        loop {
            let n = f.read(&mut buf).map_err(|e| format!("{path}: {e}"))?;
            if n == 0 {
                break;
            }
            let res = docs.feed(&buf[..n], |doc| {
                if err.is_some() {
                    return;
                }
                if let Err(e) = chrome_entry(&mut w, &doc, &mut anchor_ns, &mut first) {
                    err = Some(e.to_string());
                }
            });
            res.map_err(|e| format!("{path}: {e}"))?;
            if let Some(e) = err.take() {
                return Err(format!("{out_path}: {e}"));
            }
        }
        docs.finish(|_| {}).map_err(|e| format!("{path}: {e}"))?;
    }
    writeln!(w, "]}}").map_err(|e| e.to_string())?;
    w.flush().map_err(|e| e.to_string())?;
    Ok(())
}

fn chrome_entry(
    w: &mut impl Write,
    doc: &Json,
    anchor_ns: &mut f64,
    first: &mut bool,
) -> std::io::Result<()> {
    match doc.get("schema").and_then(|s| s.as_str()) {
        Some("trace_meta_v1") => {
            if let Some(a) = doc.get("wall_anchor_ns").and_then(|v| v.as_f64()) {
                *anchor_ns = a;
            }
            Ok(())
        }
        Some("trace_event_v1") => {
            let kind = doc.get("kind").and_then(|k| k.as_str()).unwrap_or("unknown");
            let rank = doc.get("rank").and_then(|v| v.as_f64()).unwrap_or(-1.0) as i64;
            let t_ns = doc.get("t_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let dur_ns = doc.get("dur_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let ts_us = (*anchor_ns + t_ns) / 1e3;
            let name = if kind == "coll_op" {
                let step = doc.get("step").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                format!("coll_op:{}", phase_name(step))
            } else {
                kind.to_string()
            };
            if !*first {
                write!(w, ",")?;
            }
            *first = false;
            if dur_ns > 0.0 {
                write!(
                    w,
                    "{{\"name\":\"{name}\",\"ph\":\"X\",\"ts\":{ts_us},\"dur\":{},\
                     \"pid\":{rank},\"tid\":{rank},\"args\":{}}}",
                    dur_ns / 1e3,
                    chrome_args(doc)
                )
            } else {
                write!(
                    w,
                    "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{ts_us},\
                     \"pid\":{rank},\"tid\":{rank},\"args\":{}}}",
                    chrome_args(doc)
                )
            }
        }
        _ => Ok(()),
    }
}

/// Everything except the positional fields rides along as `args`.
fn chrome_args(doc: &Json) -> Json {
    let mut args = std::collections::BTreeMap::new();
    if let Some(m) = doc.obj() {
        for (k, v) in m {
            if !matches!(k.as_str(), "schema" | "kind" | "rank" | "t_ns" | "dur_ns") {
                args.insert(k.clone(), v.clone());
            }
        }
    }
    Json::Obj(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("{name}_{}", std::process::id()))
            .to_str()
            .unwrap()
            .to_string()
    }

    fn sample_trace(path: &str) {
        let body = "{\"schema\":\"trace_meta_v1\",\"rank\":1,\"wall_anchor_ns\":5000}\n\
             {\"schema\":\"trace_event_v1\",\"kind\":\"remap_exec\",\"rank\":1,\"t_ns\":10,\
              \"dur_ns\":90,\"ns\":2,\"epoch\":1,\"step\":0,\"bytes\":1024,\"peers\":2}\n\
             {\"schema\":\"trace_event_v1\",\"kind\":\"pool_miss\",\"rank\":1,\"t_ns\":50,\
              \"dur_ns\":0,\"capacity\":4096,\"b\":0}\n";
        std::fs::write(path, body).unwrap();
    }

    #[test]
    fn fold_check_and_summary_agree() {
        let path = tmp("trace_report_fold");
        sample_trace(&path);
        let paths = vec![path.clone()];
        let fold = fold_files(&paths).unwrap();
        assert_eq!(fold.total_events(), 2);
        let rep = check_files(&paths).unwrap();
        assert_eq!((rep.lines, rep.events), (3, 2));
        assert!(rep.warnings.is_empty(), "{:?}", rep.warnings);
        let summary = render_summary(&fold);
        assert!(summary.contains("remap_exec"));
        assert!(summary.contains("pool_miss"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_accepts_hist_lines_and_folds_them() {
        let path = tmp("trace_report_hist");
        std::fs::write(
            &path,
            "{\"schema\":\"trace_meta_v1\",\"rank\":0,\"wall_anchor_ns\":1}\n\
             {\"schema\":\"trace_hist_v1\",\"rank\":0,\"hist\":\"coll_round_ns\",\
              \"count\":3,\"sum\":21,\"buckets\":[[3,3]]}\n",
        )
        .unwrap();
        let rep = check_files(&[path.clone()]).unwrap();
        assert_eq!(rep.hists, 1);
        assert_eq!(rep.events, 0);
        let fold = fold_files(&[path.clone()]).unwrap();
        let summary = render_summary(&fold);
        assert!(summary.contains("coll_round_ns"), "{summary}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_warns_on_timestamp_regression_naming_the_rank() {
        let path = tmp("trace_report_mono");
        std::fs::write(
            &path,
            "{\"schema\":\"trace_event_v1\",\"kind\":\"mark\",\"rank\":3,\"t_ns\":100,\
              \"dur_ns\":0,\"a\":0,\"b\":0}\n\
             {\"schema\":\"trace_event_v1\",\"kind\":\"mark\",\"rank\":3,\"t_ns\":40,\
              \"dur_ns\":0,\"a\":0,\"b\":0}\n",
        )
        .unwrap();
        let rep = check_files(&[path.clone()]).unwrap();
        assert_eq!(rep.warnings.len(), 1, "{:?}", rep.warnings);
        assert!(rep.warnings[0].contains("rank 3"), "{}", rep.warnings[0]);
        assert!(rep.warnings[0].contains("not monotonic"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_warns_on_anchor_skew_naming_the_rank() {
        let a = tmp("trace_report_skew_a");
        let b = tmp("trace_report_skew_b");
        std::fs::write(
            &a,
            "{\"schema\":\"trace_meta_v1\",\"rank\":0,\"wall_anchor_ns\":1000}\n\
             {\"schema\":\"trace_meta_v1\",\"rank\":2,\"wall_anchor_ns\":2000}\n",
        )
        .unwrap();
        // Rank 1's anchor is ~2 minutes from the others: a stale file.
        std::fs::write(
            &b,
            "{\"schema\":\"trace_meta_v1\",\"rank\":1,\"wall_anchor_ns\":120000000001}\n",
        )
        .unwrap();
        let rep = check_files(&[a.clone(), b.clone()]).unwrap();
        assert!(
            rep.warnings.iter().any(|w| w.contains("rank 1") && w.contains("anchor")),
            "{:?}",
            rep.warnings
        );
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn check_rejects_garbage_and_unknown_kinds() {
        let path = tmp("trace_report_bad");
        std::fs::write(&path, "{\"schema\":\"trace_event_v1\",\"kind\":\"nope\"}\n").unwrap();
        assert!(check_files(&[path.clone()]).unwrap_err().contains("unknown kind"));
        std::fs::write(&path, "not json at all\n").unwrap();
        assert!(check_files(&[path.clone()]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn chrome_export_is_loadable_json() {
        let path = tmp("trace_report_chrome_in");
        let out = tmp("trace_report_chrome_out");
        sample_trace(&path);
        write_chrome(&[path.clone()], &out).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = Json::parse(text.trim()).expect("chrome document parses");
        let events = doc.get("traceEvents").unwrap().items().unwrap();
        assert_eq!(events.len(), 2);
        // The span became a complete event, the instant an "i".
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[1].get("ph").unwrap().as_str(), Some("i"));
        // Wall-anchor alignment: ts = (5000 + 10) / 1e3.
        assert!((events[0].get("ts").unwrap().as_f64().unwrap() - 5.01).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&out).ok();
    }
}
