//! The attribution report behind `repro analyze`.
//!
//! Ties the causal graph ([`super::causal`]) to the hardware model
//! ([`crate::hardware`]): where did the wall time go (wire / compute /
//! idle, on the critical path and per rank), how do the matched wire
//! latencies distribute (p50/p95/p99, cross-checked against the
//! runtime histograms), which rank is the straggler, and how close
//! did achieved bandwidth come to the era's modeled envelope. Renders
//! a human report and a versioned `analysis_v1` JSON document for CI.

use super::causal::{
    critical_path, match_edges, phase_skews, rank_times, CausalGraph, CriticalPath, PhaseSkew,
    RankTime, Streams,
};
use super::hist::HistSnapshot;
use super::EventKind;
use crate::comm::TransportKind;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Knobs for the modeled-bandwidth comparison.
#[derive(Debug, Clone, Copy)]
pub struct AnalyzeOpts {
    /// Hardware era label for [`crate::hardware::Era::by_label`].
    pub era: &'static str,
    /// Processes per node; defaults to the trace's rank count.
    pub nppn: Option<usize>,
    /// Threads per process.
    pub ntpn: usize,
}

impl Default for AnalyzeOpts {
    fn default() -> Self {
        AnalyzeOpts { era: "amd-e9", nppn: None, ntpn: 1 }
    }
}

/// Wire traffic attributed to one transport — the `--transport` axis
/// surfaced from the stamps chunk events carry in their `b` top byte.
/// Traces from before the stamping (or non-datapath events) carry
/// code 0 and contribute to no lane; the section is omitted when
/// nothing is stamped, so old traces analyze unchanged.
#[derive(Debug, Clone, Default)]
pub struct TransportLane {
    pub name: &'static str,
    /// `chunk_send` events / wire bytes carried by this transport.
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// `chunk_arrive` events / wire bytes.
    pub msgs_recv: u64,
    pub bytes_recv: u64,
    /// Matched send→arrive edges on this transport.
    pub edges: u64,
    lat_sum_ns: u64,
    lat_n: u64,
}

impl TransportLane {
    /// Mean positive matched-edge latency (0 when none matched).
    pub fn mean_latency_ns(&self) -> u64 {
        if self.lat_n > 0 {
            self.lat_sum_ns / self.lat_n
        } else {
            0
        }
    }
}

/// Group chunk events and matched edges by their transport stamp.
fn transport_lanes(streams: &Streams, graph: &CausalGraph) -> Vec<TransportLane> {
    let mut by: BTreeMap<u8, TransportLane> = BTreeMap::new();
    for ev in &streams.events {
        if ev.transport == 0 {
            continue;
        }
        let lane = by.entry(ev.transport).or_default();
        match ev.kind {
            EventKind::ChunkSend => {
                lane.msgs_sent += 1;
                lane.bytes_sent += ev.bytes;
            }
            EventKind::ChunkArrive => {
                lane.msgs_recv += 1;
                lane.bytes_recv += ev.bytes;
            }
            _ => {}
        }
    }
    for e in &graph.edges {
        if e.transport == 0 {
            continue;
        }
        let lane = by.entry(e.transport).or_default();
        lane.edges += 1;
        if e.latency_ns > 0 {
            lane.lat_sum_ns += e.latency_ns as u64;
            lane.lat_n += 1;
        }
    }
    by.into_iter()
        .map(|(code, mut lane)| {
            lane.name = TransportKind::from_code(code).map(|k| k.name()).unwrap_or("?");
            lane
        })
        .collect()
}

/// The full analysis of one traced run.
pub struct Analysis {
    pub streams: Streams,
    pub graph: CausalGraph,
    pub path: CriticalPath,
    pub ranks: Vec<RankTime>,
    pub phases: Vec<PhaseSkew>,
    /// Wire traffic per transport stamp (empty for unstamped traces).
    pub transports: Vec<TransportLane>,
    /// Aligned first-event → last-event-end span across all ranks.
    pub wall_ns: u64,
    /// Total `chunk_send` bytes / wall seconds.
    pub achieved_bw: f64,
    /// [`crate::hardware::NodeModel::node_bandwidth`] for the opts.
    pub modeled_bw: f64,
    pub era: &'static str,
    pub nppn: usize,
    pub ntpn: usize,
    /// Sorted positive matched-edge latencies (ns), for percentiles.
    latencies: Vec<u64>,
    pub warnings: Vec<String>,
}

/// Parse trace files and run the whole pipeline.
pub fn analyze_files(paths: &[String], opts: &AnalyzeOpts) -> Result<Analysis, String> {
    Ok(analyze_streams(Streams::from_files(paths)?, opts))
}

/// Analyze already-parsed streams (tests build these synthetically).
pub fn analyze_streams(streams: Streams, opts: &AnalyzeOpts) -> Analysis {
    let graph = match_edges(&streams);
    let path = critical_path(&streams, &graph);
    let ranks = rank_times(&streams);
    let phases = phase_skews(&streams);
    let transports = transport_lanes(&streams, &graph);
    let t0 = ranks.iter().map(|r| r.t0_ns).min().unwrap_or(0);
    let t1 = ranks.iter().map(|r| r.t1_ns).max().unwrap_or(0);
    let wall_ns = t1.saturating_sub(t0);
    let bytes_sent: u64 = ranks.iter().map(|r| r.bytes_sent).sum();
    let achieved_bw =
        if wall_ns > 0 { bytes_sent as f64 / (wall_ns as f64 / 1e9) } else { 0.0 };
    let nppn = opts.nppn.unwrap_or_else(|| ranks.len().max(1));
    let modeled_bw = crate::hardware::Era::by_label(opts.era)
        .map(|era| crate::hardware::NodeModel::new(era, nppn, opts.ntpn).node_bandwidth())
        .unwrap_or(0.0);

    let mut latencies: Vec<u64> =
        graph.edges.iter().filter(|e| e.latency_ns > 0).map(|e| e.latency_ns as u64).collect();
    latencies.sort_unstable();

    let mut warnings = Vec::new();
    if graph.skew_exceeds_min_latency() {
        warnings.push(format!(
            "estimated clock skew ({} ns) exceeds the smallest matched latency ({} ns); \
             individual edge latencies are unreliable",
            graph.skew_est_ns, graph.min_latency_ns
        ));
    }
    let dropped = streams.total_dropped();
    if dropped > 0 {
        warnings.push(format!(
            "{dropped} events were dropped by ring wrap; edges and attribution are partial"
        ));
    }
    if graph.unmatched_sends + graph.unmatched_arrives > 0 {
        warnings.push(format!(
            "{} sends / {} arrives had no counterpart (ring wrap, untraced peer, or \
             truncated file)",
            graph.unmatched_sends, graph.unmatched_arrives
        ));
    }

    Analysis {
        streams,
        graph,
        path,
        ranks,
        phases,
        transports,
        wall_ns,
        achieved_bw,
        modeled_bw,
        era: opts.era,
        nppn,
        ntpn: opts.ntpn,
        latencies,
        warnings,
    }
}

impl Analysis {
    /// Nearest-rank percentile over the matched positive latencies.
    pub fn latency_pctile(&self, q: f64) -> u64 {
        if self.latencies.is_empty() {
            return 0;
        }
        let idx = ((q * self.latencies.len() as f64).ceil() as usize)
            .clamp(1, self.latencies.len());
        self.latencies[idx - 1]
    }

    /// Histograms merged across ranks, keyed by hist name.
    pub fn merged_hists(&self) -> BTreeMap<String, HistSnapshot> {
        let mut out: BTreeMap<String, HistSnapshot> = BTreeMap::new();
        for ((_rank, name), snap) in &self.streams.hists {
            out.entry(name.clone()).or_insert_with(HistSnapshot::new).merge(snap);
        }
        out
    }

    /// The human report.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "== trace analysis ==");
        let _ = writeln!(
            s,
            "ranks {}  events {}  wall {}",
            self.ranks.len(),
            self.streams.events.len(),
            fmt_ns(self.wall_ns)
        );
        let _ = writeln!(
            s,
            "edges matched {}  unmatched sends {}  unmatched arrives {}  skew est {}",
            self.graph.edges.len(),
            self.graph.unmatched_sends,
            self.graph.unmatched_arrives,
            fmt_ns(self.graph.skew_est_ns)
        );
        if !self.latencies.is_empty() {
            let _ = writeln!(
                s,
                "wire latency p50 {}  p95 {}  p99 {}  (n={})",
                fmt_ns(self.latency_pctile(0.50)),
                fmt_ns(self.latency_pctile(0.95)),
                fmt_ns(self.latency_pctile(0.99)),
                self.latencies.len()
            );
        }
        let _ = writeln!(
            s,
            "bandwidth achieved {:.3} GB/s  modeled ({} nppn={} ntpn={}) {:.3} GB/s  ({:.1}%)",
            self.achieved_bw / 1e9,
            self.era,
            self.nppn,
            self.ntpn,
            self.modeled_bw / 1e9,
            if self.modeled_bw > 0.0 { 100.0 * self.achieved_bw / self.modeled_bw } else { 0.0 }
        );

        let _ = writeln!(s, "\n-- critical path --");
        let covered: u64 = self.path.segments.iter().map(|x| x.dur_ns()).sum();
        let _ = writeln!(
            s,
            "span {}  segments {}  covered {}",
            fmt_ns(self.path.total_ns()),
            self.path.segments.len(),
            fmt_ns(covered)
        );
        for (label, ns) in self.path.breakdown() {
            let pct = if covered > 0 { 100.0 * ns as f64 / covered as f64 } else { 0.0 };
            let _ = writeln!(s, "  {label:<16} {:>12}  {pct:5.1}%", fmt_ns(ns));
        }

        let _ = writeln!(s, "\n-- per rank --");
        let _ = writeln!(
            s,
            "  {:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
            "rank", "wall", "busy", "idle", "sent", "recv", "events"
        );
        for r in &self.ranks {
            let _ = writeln!(
                s,
                "  {:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
                r.rank,
                fmt_ns(r.wall_ns()),
                fmt_ns(r.busy_ns),
                fmt_ns(r.idle_ns()),
                fmt_bytes(r.bytes_sent),
                fmt_bytes(r.bytes_recv),
                r.events
            );
        }

        if !self.transports.is_empty() {
            let _ = writeln!(s, "\n-- wire by transport --");
            let _ = writeln!(
                s,
                "  {:<9} {:>10} {:>12} {:>10} {:>12} {:>8} {:>12}",
                "transport", "sends", "sent", "recvs", "recvd", "edges", "mean lat"
            );
            for l in &self.transports {
                let _ = writeln!(
                    s,
                    "  {:<9} {:>10} {:>12} {:>10} {:>12} {:>8} {:>12}",
                    l.name,
                    l.msgs_sent,
                    fmt_bytes(l.bytes_sent),
                    l.msgs_recv,
                    fmt_bytes(l.bytes_recv),
                    l.edges,
                    fmt_ns(l.mean_latency_ns())
                );
            }
        }

        if !self.phases.is_empty() {
            let _ = writeln!(s, "\n-- collective phases (worst skew first) --");
            let _ = writeln!(
                s,
                "  {:<16} {:>6} {:>12} {:>12} {:>12} {:>6} {:>6}",
                "phase", "ops", "total", "median/rank", "max/rank", "rank", "skew"
            );
            for p in &self.phases {
                let _ = writeln!(
                    s,
                    "  {:<16} {:>6} {:>12} {:>12} {:>12} {:>6} {:>6.2}",
                    p.phase,
                    p.count,
                    fmt_ns(p.total_ns),
                    fmt_ns(p.median_rank_ns),
                    fmt_ns(p.max_rank_ns),
                    p.max_rank,
                    p.skew
                );
            }
            if let Some(worst) = self.phases.first() {
                if worst.skew > 1.05 {
                    let _ = writeln!(
                        s,
                        "straggler: rank {} in {} ({:.2}x the median rank)",
                        worst.max_rank, worst.phase, worst.skew
                    );
                }
            }
        }

        let hists = self.merged_hists();
        if !hists.is_empty() {
            let _ = writeln!(s, "\n-- runtime histograms (merged across ranks) --");
            let _ = writeln!(
                s,
                "  {:<20} {:>10} {:>12} {:>12} {:>12} {:>12}",
                "hist", "count", "mean", "p50", "p95", "p99"
            );
            for (name, h) in &hists {
                let _ = writeln!(
                    s,
                    "  {:<20} {:>10} {:>12} {:>12} {:>12} {:>12}",
                    name,
                    h.count,
                    fmt_ns(h.mean() as u64),
                    fmt_ns(h.quantile(0.50)),
                    fmt_ns(h.quantile(0.95)),
                    fmt_ns(h.quantile(0.99))
                );
            }
        }

        for w in &self.warnings {
            let _ = writeln!(s, "warning: {w}");
        }
        s
    }

    /// The versioned machine-readable document CI consumes.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(2048);
        let _ = write!(
            s,
            "{{\"schema\":\"analysis_v1\",\"ranks\":{},\"events\":{},\"wall_ns\":{},\
             \"matched_edges\":{},\"unmatched_sends\":{},\"unmatched_arrives\":{},\
             \"dropped\":{},\"clock_skew_ns_est\":{}",
            self.ranks.len(),
            self.streams.events.len(),
            self.wall_ns,
            self.graph.edges.len(),
            self.graph.unmatched_sends,
            self.graph.unmatched_arrives,
            self.streams.total_dropped(),
            self.graph.skew_est_ns
        );
        let _ = write!(
            s,
            ",\"latency_ns\":{{\"p50\":{},\"p95\":{},\"p99\":{},\"n\":{}}}",
            self.latency_pctile(0.50),
            self.latency_pctile(0.95),
            self.latency_pctile(0.99),
            self.latencies.len()
        );
        let _ = write!(
            s,
            ",\"achieved_gb_per_sec\":{},\"modeled_gb_per_sec\":{},\
             \"model\":{{\"era\":\"{}\",\"nppn\":{},\"ntpn\":{}}}",
            fmt_f64(self.achieved_bw / 1e9),
            fmt_f64(self.modeled_bw / 1e9),
            self.era,
            self.nppn,
            self.ntpn
        );
        if let Some(worst) = self.phases.first() {
            let _ = write!(
                s,
                ",\"straggler\":{{\"rank\":{},\"phase\":\"{}\",\"skew\":{}}}",
                worst.max_rank,
                worst.phase,
                fmt_f64(worst.skew)
            );
        }
        // Critical path: totals, per-label breakdown, and the largest
        // segments (enough for CI assertions and a quick look).
        let covered: u64 = self.path.segments.iter().map(|x| x.dur_ns()).sum();
        let _ = write!(
            s,
            ",\"critical_path\":{{\"total_ns\":{},\"covered_ns\":{},\"segments\":{},\
             \"breakdown\":{{",
            self.path.total_ns(),
            covered,
            self.path.segments.len()
        );
        for (i, (label, ns)) in self.path.breakdown().into_iter().enumerate() {
            let _ = write!(s, "{}\"{label}\":{ns}", if i > 0 { "," } else { "" });
        }
        s.push_str("},\"top\":[");
        let mut top: Vec<_> = self.path.segments.clone();
        top.sort_by_key(|x| std::cmp::Reverse(x.dur_ns()));
        for (i, seg) in top.iter().take(8).enumerate() {
            let _ = write!(
                s,
                "{}{{\"rank\":{},\"label\":\"{}\",\"t0_ns\":{},\"dur_ns\":{}}}",
                if i > 0 { "," } else { "" },
                seg.rank,
                seg.label,
                seg.t0_ns,
                seg.dur_ns()
            );
        }
        s.push_str("]}");

        s.push_str(",\"per_rank\":[");
        for (i, r) in self.ranks.iter().enumerate() {
            let _ = write!(
                s,
                "{}{{\"rank\":{},\"wall_ns\":{},\"busy_ns\":{},\"idle_ns\":{},\
                 \"bytes_sent\":{},\"bytes_recv\":{},\"events\":{}}}",
                if i > 0 { "," } else { "" },
                r.rank,
                r.wall_ns(),
                r.busy_ns,
                r.idle_ns(),
                r.bytes_sent,
                r.bytes_recv,
                r.events
            );
        }
        s.push(']');

        s.push_str(",\"transports\":[");
        for (i, l) in self.transports.iter().enumerate() {
            let _ = write!(
                s,
                "{}{{\"transport\":\"{}\",\"msgs_sent\":{},\"bytes_sent\":{},\
                 \"msgs_recv\":{},\"bytes_recv\":{},\"edges\":{},\"mean_latency_ns\":{}}}",
                if i > 0 { "," } else { "" },
                l.name,
                l.msgs_sent,
                l.bytes_sent,
                l.msgs_recv,
                l.bytes_recv,
                l.edges,
                l.mean_latency_ns()
            );
        }
        s.push(']');

        s.push_str(",\"phases\":[");
        for (i, p) in self.phases.iter().enumerate() {
            let _ = write!(
                s,
                "{}{{\"phase\":\"{}\",\"ops\":{},\"total_ns\":{},\"median_rank_ns\":{},\
                 \"max_rank_ns\":{},\"max_rank\":{},\"skew\":{}}}",
                if i > 0 { "," } else { "" },
                p.phase,
                p.count,
                p.total_ns,
                p.median_rank_ns,
                p.max_rank_ns,
                p.max_rank,
                fmt_f64(p.skew)
            );
        }
        s.push(']');

        s.push_str(",\"hists\":[");
        for (i, (name, h)) in self.merged_hists().iter().enumerate() {
            let _ = write!(
                s,
                "{}{{\"hist\":\"{}\",\"count\":{},\"sum\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}",
                if i > 0 { "," } else { "" },
                name,
                h.count,
                h.sum,
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99)
            );
        }
        s.push(']');

        s.push_str(",\"warnings\":[");
        for (i, w) in self.warnings.iter().enumerate() {
            let _ = write!(s, "{}\"{}\"", if i > 0 { "," } else { "" }, escape(w));
        }
        s.push_str("]}");
        s
    }
}

/// A JSON-safe float: finite values as-is, NaN/inf as `null`.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".into()
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Human-scale nanoseconds: `982ns`, `14.3us`, `2.1ms`, `1.50s`.
pub fn fmt_ns(ns: u64) -> String {
    let v = ns as f64;
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}us", v / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", v / 1e6)
    } else {
        format!("{:.2}s", v / 1e9)
    }
}

fn fmt_bytes(b: u64) -> String {
    let v = b as f64;
    if b < 1 << 10 {
        format!("{b}B")
    } else if b < 1 << 20 {
        format!("{:.1}KiB", v / 1024.0)
    } else if b < 1 << 30 {
        format!("{:.1}MiB", v / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GiB", v / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::super::causal::CEvent;
    use super::super::EventKind;
    use super::*;
    use crate::json::Json;

    fn ev(kind: EventKind, rank: i64, peer: i64, at_ns: u64, dur_ns: u64, step: u64) -> CEvent {
        CEvent {
            t_ns: at_ns,
            dur_ns,
            at_ns,
            kind,
            rank,
            peer,
            ns: 8,
            epoch: 1,
            step,
            bytes: 1 << 20,
            transport: 0,
        }
    }

    fn four_rank_streams() -> Streams {
        let mut s = Streams::default();
        for r in 0..4i64 {
            s.events.push(ev(EventKind::RemapExec, r, -1, 0, 50, 0));
        }
        // Ring: r sends to r+1 at t=50, arrives at t=80.
        for r in 0..3i64 {
            s.events.push(ev(EventKind::ChunkSend, r, r + 1, 50, 0, r as u64));
            s.events.push(ev(EventKind::ChunkArrive, r + 1, r, 70, 10, r as u64));
        }
        s.events.push(ev(EventKind::CollOp, 3, -1, 80, 120, 5 << 16));
        s
    }

    #[test]
    fn analysis_json_is_valid_and_versioned() {
        let a = analyze_streams(four_rank_streams(), &AnalyzeOpts::default());
        let doc = Json::parse(&a.to_json()).expect("analysis_v1 parses");
        assert_eq!(doc.get("schema").unwrap().as_str(), Some("analysis_v1"));
        assert_eq!(doc.get("ranks").unwrap().as_usize(), Some(4));
        assert_eq!(doc.get("matched_edges").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("unmatched_sends").unwrap().as_usize(), Some(0));
        let cp = doc.get("critical_path").unwrap();
        assert!(cp.get("segments").unwrap().as_usize().unwrap() > 0);
        // The path covers the whole wall span.
        let wall = doc.get("wall_ns").unwrap().as_usize().unwrap();
        assert_eq!(cp.get("total_ns").unwrap().as_usize().unwrap(), wall);
        assert!(doc.get("modeled_gb_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn transport_lanes_attribute_wire_traffic_per_stamp() {
        let mut s = Streams::default();
        // One shmem hop and one tcp hop — a hybrid run's shape.
        for (i, code) in
            [TransportKind::Shmem.code(), TransportKind::Tcp.code()].into_iter().enumerate()
        {
            let mut snd = ev(EventKind::ChunkSend, 0, 1, 100, 0, i as u64);
            snd.transport = code;
            let mut arr = ev(EventKind::ChunkArrive, 1, 0, 150 + 50 * i as u64, 0, i as u64);
            arr.transport = code;
            s.events.push(snd);
            s.events.push(arr);
        }
        let a = analyze_streams(s, &AnalyzeOpts::default());
        assert_eq!(a.transports.len(), 2);
        assert_eq!(a.transports[0].name, "shmem");
        assert_eq!(a.transports[1].name, "tcp");
        for l in &a.transports {
            assert_eq!((l.msgs_sent, l.msgs_recv, l.edges), (1, 1, 1), "{}", l.name);
            assert_eq!(l.bytes_sent, 1 << 20);
            assert!(l.mean_latency_ns() > 0, "{}", l.name);
        }
        let text = a.render();
        assert!(text.contains("wire by transport"), "{text}");
        let doc = Json::parse(&a.to_json()).expect("analysis_v1 parses");
        let lanes = doc.get("transports").unwrap().items().expect("array");
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].get("transport").unwrap().as_str(), Some("shmem"));
        assert!(lanes[1].get("mean_latency_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn unstamped_traces_omit_the_transport_section() {
        let a = analyze_streams(four_rank_streams(), &AnalyzeOpts::default());
        assert!(a.transports.is_empty());
        assert!(!a.render().contains("wire by transport"));
    }

    #[test]
    fn per_rank_idle_plus_busy_equals_wall() {
        let a = analyze_streams(four_rank_streams(), &AnalyzeOpts::default());
        for r in &a.ranks {
            assert_eq!(r.busy_ns + r.idle_ns(), r.wall_ns(), "rank {}", r.rank);
        }
    }

    #[test]
    fn render_names_the_straggler_and_warns_on_skew() {
        let mut s = four_rank_streams();
        // Rank 2 is 10x slower in reduce_scatter.
        for r in 0..4i64 {
            let dur = if r == 2 { 1000 } else { 100 };
            s.events.push(ev(EventKind::CollOp, r, -1, 200, dur, 5 << 16));
        }
        let a = analyze_streams(s, &AnalyzeOpts::default());
        let text = a.render();
        assert!(text.contains("straggler: rank 2"), "{text}");
    }

    #[test]
    fn empty_input_renders_without_panic() {
        let a = analyze_streams(Streams::default(), &AnalyzeOpts::default());
        let _ = a.render();
        let doc = Json::parse(&a.to_json()).expect("parses");
        assert_eq!(doc.get("matched_edges").unwrap().as_usize(), Some(0));
    }
}
