//! Causal joining of per-rank trace streams.
//!
//! PR 7's telemetry plane produces P independent timelines; this
//! module turns them into one causal graph. A `chunk_send` on rank
//! *s* matches the `chunk_arrive` on rank *r* that carries the same
//! bit-field tag `(ns, epoch, step)` with `peer` pointing back — the
//! step field's low 16 bits are the chunk index, so the key is unique
//! per in-flight chunk of a stream. Per-rank monotonic clocks are
//! aligned by each stream's `trace_meta_v1` wall anchor
//! (`aligned = wall_anchor_ns + t_ns`); the residual cross-process
//! clock skew is *estimated* from the matched edges themselves (a
//! negative wire latency is impossible, so its magnitude is a lower
//! bound on skew) and reported rather than hidden.
//!
//! From the edge graph the module derives the three attribution
//! primitives `repro analyze` reports: the run's **critical path**
//! (walk backward from the last event; an arrive jumps to its matched
//! send, anything else to its rank predecessor), per-rank
//! **busy/idle time** (union of recorded spans vs. the rank's wall
//! span), and a **straggler ranking** (max/median per-rank time per
//! collective phase).
//!
//! Everything degrades, nothing panics: unmatched sends/arrives (ring
//! wrap, a dead rank, a truncated file) are counted and the graph is
//! built from what matched.

use super::fold::phase_name;
use super::hist::HistSnapshot;
use super::EventKind;
use crate::comm::TransportKind;
use crate::json::{Json, StreamDocs};
use std::collections::BTreeMap;
use std::io::Read;

/// One trace event, parsed into the compact shape matching needs.
#[derive(Debug, Clone, Copy)]
pub struct CEvent {
    /// Monotonic start since the stream's anchor.
    pub t_ns: u64,
    pub dur_ns: u64,
    /// Aligned start: stream wall anchor + `t_ns`.
    pub at_ns: u64,
    pub kind: EventKind,
    pub rank: i64,
    /// Peer rank (-1 when absent).
    pub peer: i64,
    /// Unpacked tag fields (`0,0,0` when the event carried none).
    pub ns: u64,
    pub epoch: u64,
    pub step: u64,
    /// The kind's primary payload (`bytes` for data-movement kinds).
    pub bytes: u64,
    /// Wire code of the transport that carried a chunk event
    /// ([`TransportKind::code`]; 0 = unstamped / not a chunk event).
    pub transport: u8,
}

impl CEvent {
    /// Aligned end of the event's span.
    pub fn end_ns(&self) -> u64 {
        self.at_ns + self.dur_ns
    }
}

/// One matched message edge: `chunk_send` on `from` → `chunk_arrive`
/// on `to`, timestamps aligned.
#[derive(Debug, Clone, Copy)]
pub struct Edge {
    pub from: i64,
    pub to: i64,
    /// Aligned send instant.
    pub send_ns: u64,
    /// Aligned arrival completion.
    pub arrive_ns: u64,
    /// Wire bytes.
    pub bytes: u64,
    /// Signed wire latency (`arrive - send`; negative under clock
    /// skew — kept signed so skew stays visible).
    pub latency_ns: i64,
    /// Wire code of the carrying transport (the send's stamp, falling
    /// back to the arrive's; 0 = neither side was stamped).
    pub transport: u8,
}

impl Edge {
    /// The carrying transport's trace label (`"?"` when unstamped).
    pub fn transport_name(&self) -> &'static str {
        TransportKind::from_code(self.transport).map(|k| k.name()).unwrap_or("?")
    }
}

/// All streams of one run, parsed and indexed for matching.
#[derive(Debug, Default)]
pub struct Streams {
    /// Every parsed event, in file order.
    pub events: Vec<CEvent>,
    /// Opening wall anchor per rank (first one seen wins).
    pub anchors: BTreeMap<i64, u64>,
    /// Ring drop count per rank (closing meta lines).
    pub dropped: BTreeMap<i64, u64>,
    /// Folded `trace_hist_v1` lines per (rank, hist name), last wins.
    pub hists: BTreeMap<(i64, String), HistSnapshot>,
    /// Lines that were valid JSON but no recognized schema/kind.
    pub skipped: u64,
}

const READ_CHUNK: usize = 64 * 1024;

impl Streams {
    /// Stream-parse NDJSON trace files. Each file carries one wall
    /// anchor (its opening meta line); every event line in the file is
    /// aligned with it — a file may interleave events of many ranks
    /// (in-process SPMD shares one ring), which is why the anchor is
    /// per *file*, not per rank.
    pub fn from_files(paths: &[String]) -> Result<Streams, String> {
        let mut out = Streams::default();
        for path in paths {
            let mut f = std::fs::File::open(path).map_err(|e| format!("{path}: {e}"))?;
            let mut docs = StreamDocs::new();
            let mut buf = vec![0u8; READ_CHUNK];
            let mut anchor = 0u64;
            loop {
                let n = f.read(&mut buf).map_err(|e| format!("{path}: {e}"))?;
                if n == 0 {
                    break;
                }
                docs.feed(&buf[..n], |doc| out.add_doc(&doc, &mut anchor))
                    .map_err(|e| format!("{path}: {e}"))?;
            }
            docs.finish(|doc| out.add_doc(&doc, &mut anchor))
                .map_err(|e| format!("{path}: {e}"))?;
        }
        Ok(out)
    }

    /// Fold one parsed document (`anchor` is the current file's).
    pub fn add_doc(&mut self, doc: &Json, anchor: &mut u64) {
        let rank = doc.get("rank").and_then(|r| r.as_f64()).map(|r| r as i64).unwrap_or(-1);
        match doc.get("schema").and_then(|s| s.as_str()) {
            Some("trace_meta_v1") => {
                if let Some(w) = doc.get("wall_anchor_ns").and_then(|v| v.as_f64()) {
                    *anchor = w as u64;
                    self.anchors.entry(rank).or_insert(*anchor);
                }
                if let Some(d) = doc.get("dropped").and_then(|v| v.as_f64()) {
                    let e = self.dropped.entry(rank).or_insert(0);
                    *e = (*e).max(d as u64);
                }
            }
            Some("trace_event_v1") => {
                let name = doc.get("kind").and_then(|k| k.as_str()).unwrap_or("");
                let Some(kind) = super::kind_from_name(name) else {
                    self.skipped += 1;
                    return;
                };
                let num = |k: &str| doc.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                let t_ns = num("t_ns");
                // In-process SPMD rings share one anchor; spawned
                // ranks each bring their own via their file's meta.
                let at_ns = anchor.saturating_add(t_ns);
                self.events.push(CEvent {
                    t_ns,
                    dur_ns: num("dur_ns"),
                    at_ns,
                    kind,
                    rank,
                    peer: doc.get("peer").and_then(|v| v.as_f64()).map(|p| p as i64).unwrap_or(-1),
                    ns: num("ns"),
                    epoch: num("epoch"),
                    step: num("step"),
                    bytes: num("bytes"),
                    transport: doc
                        .get("transport")
                        .and_then(|v| v.as_str())
                        .and_then(TransportKind::parse)
                        .map(|k| k.code())
                        .unwrap_or(0),
                });
                // The per-file anchor also covers events recorded
                // before any rank was attributed: nothing else needed.
                if !self.anchors.contains_key(&rank) && *anchor > 0 {
                    self.anchors.insert(rank, *anchor);
                }
            }
            Some("trace_hist_v1") => {
                if let Some(name) = doc.get("hist").and_then(|h| h.as_str()) {
                    // Cumulative totals: the latest line supersedes.
                    self.hists.insert((rank, name.to_string()), HistSnapshot::from_doc(doc));
                }
            }
            _ => self.skipped += 1,
        }
    }

    /// Total ring drops across every rank.
    pub fn total_dropped(&self) -> u64 {
        self.dropped.values().sum()
    }
}

/// The causal join of a run's streams: matched edges, the leftovers,
/// and the skew estimate.
#[derive(Debug, Default)]
pub struct CausalGraph {
    pub edges: Vec<Edge>,
    pub unmatched_sends: u64,
    pub unmatched_arrives: u64,
    /// Estimated cross-rank clock skew (ns): the largest negative
    /// matched latency's magnitude — a hard lower bound on how far
    /// two anchors disagree.
    pub skew_est_ns: u64,
    /// Smallest positive matched latency (ns); 0 when no edge has one.
    pub min_latency_ns: u64,
}

impl CausalGraph {
    /// Does the estimated skew exceed the smallest matched latency —
    /// i.e., are individual edge latencies untrustworthy?
    pub fn skew_exceeds_min_latency(&self) -> bool {
        self.skew_est_ns > 0 && self.skew_est_ns > self.min_latency_ns
    }
}

/// Join `chunk_send`/`chunk_arrive` events into message edges.
///
/// Key: `(ns, epoch, step, sender, receiver)` — the full bit-field
/// tag (step carries `lane | chunk`) plus both endpoints, so ring
/// forwards of the same chunk on different hops stay distinct.
/// Duplicate keys (an epoch reused across bench iterations) pair in
/// time order; surplus on either side is counted unmatched, never an
/// error — the matcher must survive ring wrap and dead ranks.
pub fn match_edges(streams: &Streams) -> CausalGraph {
    type Key = (u64, u64, u64, i64, i64);
    let mut sends: BTreeMap<Key, Vec<(u64, u64, u8)>> = BTreeMap::new();
    let mut arrives: BTreeMap<Key, Vec<(u64, u64, u8)>> = BTreeMap::new();
    for ev in &streams.events {
        match ev.kind {
            EventKind::ChunkSend => sends
                .entry((ev.ns, ev.epoch, ev.step, ev.rank, ev.peer))
                .or_default()
                .push((ev.at_ns, ev.bytes, ev.transport)),
            EventKind::ChunkArrive => arrives
                .entry((ev.ns, ev.epoch, ev.step, ev.peer, ev.rank))
                .or_default()
                .push((ev.end_ns(), ev.bytes, ev.transport)),
            _ => {}
        }
    }
    let mut g = CausalGraph::default();
    let mut min_pos = u64::MAX;
    for (key, mut ss) in sends {
        let (_, _, _, from, to) = key;
        match arrives.remove(&key) {
            None => g.unmatched_sends += ss.len() as u64,
            Some(mut aa) => {
                ss.sort_unstable();
                aa.sort_unstable();
                let n = ss.len().min(aa.len());
                g.unmatched_sends += (ss.len() - n) as u64;
                g.unmatched_arrives += (aa.len() - n) as u64;
                for i in 0..n {
                    let (send_ns, bytes, st) = ss[i];
                    let (arrive_ns, _, at) = aa[i];
                    let latency_ns = arrive_ns as i64 - send_ns as i64;
                    if latency_ns < 0 {
                        g.skew_est_ns = g.skew_est_ns.max(latency_ns.unsigned_abs());
                    } else if latency_ns > 0 {
                        min_pos = min_pos.min(latency_ns as u64);
                    }
                    let transport = if st != 0 { st } else { at };
                    g.edges.push(Edge {
                        from,
                        to,
                        send_ns,
                        arrive_ns,
                        bytes,
                        latency_ns,
                        transport,
                    });
                }
            }
        }
    }
    g.unmatched_arrives += arrives.values().map(|v| v.len() as u64).sum::<u64>();
    if min_pos != u64::MAX {
        g.min_latency_ns = min_pos;
    }
    g
}

/// One critical-path segment, most recent first in the walk but
/// returned oldest-first.
#[derive(Debug, Clone)]
pub struct Segment {
    pub rank: i64,
    /// Aligned start/end.
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// `"wire"` for a message edge, `"idle"` for a wait gap, else the
    /// event kind name.
    pub label: &'static str,
}

impl Segment {
    pub fn dur_ns(&self) -> u64 {
        self.t1_ns.saturating_sub(self.t0_ns)
    }
}

/// The run's critical path: a contiguous chain of segments from the
/// globally earliest event to the latest event end.
#[derive(Debug, Default)]
pub struct CriticalPath {
    pub segments: Vec<Segment>,
    /// Aligned span the path covers.
    pub start_ns: u64,
    pub end_ns: u64,
}

impl CriticalPath {
    pub fn total_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Time on the path by label.
    pub fn breakdown(&self) -> BTreeMap<&'static str, u64> {
        let mut out = BTreeMap::new();
        for s in &self.segments {
            *out.entry(s.label).or_insert(0) += s.dur_ns();
        }
        out
    }
}

/// Compute the critical path by backward walk: start at the event
/// with the latest aligned end; a matched `chunk_arrive` jumps across
/// the wire to its send (on the sending rank), anything else steps to
/// the previous event on the same rank. Gaps between consecutive
/// events on a rank become `idle` segments; the prefix from the
/// globally earliest event to where the walk terminates becomes a
/// leading `idle` segment — so the path always covers the measured
/// wall span. Returns an empty path for an empty run.
pub fn critical_path(streams: &Streams, graph: &CausalGraph) -> CriticalPath {
    if streams.events.is_empty() {
        return CriticalPath::default();
    }
    // Per-rank event lists sorted by aligned end.
    let mut per_rank: BTreeMap<i64, Vec<usize>> = BTreeMap::new();
    for (i, ev) in streams.events.iter().enumerate() {
        per_rank.entry(ev.rank).or_default().push(i);
    }
    for list in per_rank.values_mut() {
        list.sort_by_key(|&i| (streams.events[i].end_ns(), streams.events[i].at_ns));
    }
    // Arrive → edge lookup: key by (rank, aligned end) of the arrive.
    let mut edge_by_arrive: BTreeMap<(i64, u64), &Edge> = BTreeMap::new();
    for e in &graph.edges {
        edge_by_arrive.entry((e.to, e.arrive_ns)).or_insert(e);
    }
    let global_start = streams.events.iter().map(|e| e.at_ns).min().unwrap_or(0);
    let (last_rank, last_idx) = per_rank
        .iter()
        .filter_map(|(&r, list)| list.last().map(|&i| (r, i)))
        .max_by_key(|&(_, i)| streams.events[i].end_ns())
        .expect("nonempty run");
    let end_ns = streams.events[last_idx].end_ns();

    let mut segs: Vec<Segment> = Vec::new();
    let mut rank = last_rank;
    // Position within the current rank's sorted list.
    let mut pos = per_rank[&rank].len() - 1;
    let mut cursor = end_ns;
    // Bounded walk: each step consumes one event or one edge.
    let budget = streams.events.len() + graph.edges.len() + 8;
    for _ in 0..budget {
        let list = &per_rank[&rank];
        let i = list[pos];
        let ev = &streams.events[i];
        let (start, end) = (ev.at_ns.min(cursor), ev.end_ns().min(cursor));
        if end > start {
            segs.push(Segment {
                rank,
                t0_ns: start,
                t1_ns: end,
                label: super::kind_name(ev.kind),
            });
        }
        cursor = start;
        // A matched arrival: cross the wire to the sender.
        if ev.kind == EventKind::ChunkArrive {
            if let Some(edge) = edge_by_arrive.get(&(rank, ev.end_ns())) {
                if edge.send_ns < cursor {
                    segs.push(Segment {
                        rank: edge.from,
                        t0_ns: edge.send_ns,
                        t1_ns: cursor,
                        label: "wire",
                    });
                    cursor = edge.send_ns;
                }
                let Some((npos, _)) = per_rank
                    .get(&edge.from)
                    .and_then(|l| {
                        l.iter()
                            .enumerate()
                            .rev()
                            .find(|&(_, &j)| streams.events[j].end_ns() <= edge.send_ns)
                    })
                else {
                    break;
                };
                rank = edge.from;
                pos = npos;
                continue;
            }
        }
        // Step to the rank's previous event; the gap is idle time.
        if pos == 0 {
            break;
        }
        pos -= 1;
        let prev_end = streams.events[list[pos]].end_ns();
        if prev_end < cursor {
            segs.push(Segment { rank, t0_ns: prev_end, t1_ns: cursor, label: "idle" });
            cursor = prev_end;
        }
    }
    if global_start < cursor {
        // Startup slack: the chain's origin rank waited since the
        // run's earliest recorded instant.
        segs.push(Segment { rank, t0_ns: global_start, t1_ns: cursor, label: "idle" });
    }
    segs.reverse();
    CriticalPath { segments: segs, start_ns: global_start, end_ns }
}

/// Per-rank busy/idle attribution over the rank's own wall span.
#[derive(Debug, Clone, Copy, Default)]
pub struct RankTime {
    pub rank: i64,
    /// Aligned first event start / last event end.
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// Union of recorded span durations (overlaps merged).
    pub busy_ns: u64,
    pub events: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
}

impl RankTime {
    pub fn wall_ns(&self) -> u64 {
        self.t1_ns.saturating_sub(self.t0_ns)
    }

    /// Wall minus busy — by construction `busy + idle == wall`.
    pub fn idle_ns(&self) -> u64 {
        self.wall_ns().saturating_sub(self.busy_ns)
    }
}

/// Compute per-rank busy (merged span union) and idle time.
pub fn rank_times(streams: &Streams) -> Vec<RankTime> {
    let mut spans: BTreeMap<i64, Vec<(u64, u64)>> = BTreeMap::new();
    let mut out: BTreeMap<i64, RankTime> = BTreeMap::new();
    for ev in &streams.events {
        let rt = out.entry(ev.rank).or_insert_with(|| RankTime {
            rank: ev.rank,
            t0_ns: u64::MAX,
            ..Default::default()
        });
        rt.t0_ns = rt.t0_ns.min(ev.at_ns);
        rt.t1_ns = rt.t1_ns.max(ev.end_ns());
        rt.events += 1;
        match ev.kind {
            EventKind::ChunkSend => rt.bytes_sent += ev.bytes,
            EventKind::ChunkArrive => rt.bytes_recv += ev.bytes,
            _ => {}
        }
        if ev.dur_ns > 0 {
            spans.entry(ev.rank).or_default().push((ev.at_ns, ev.end_ns()));
        }
    }
    for (rank, mut list) in spans {
        list.sort_unstable();
        let mut busy = 0u64;
        let mut cur: Option<(u64, u64)> = None;
        for (lo, hi) in list {
            match &mut cur {
                Some((_, chi)) if lo <= *chi => *chi = (*chi).max(hi),
                _ => {
                    if let Some((clo, chi)) = cur {
                        busy += chi - clo;
                    }
                    cur = Some((lo, hi));
                }
            }
        }
        if let Some((clo, chi)) = cur {
            busy += chi - clo;
        }
        if let Some(rt) = out.get_mut(&rank) {
            rt.busy_ns = busy.min(rt.wall_ns());
        }
    }
    out.into_values().collect()
}

/// Straggler statistics for one collective phase: per-rank total
/// `coll_op` time, its spread, and the slowest rank.
#[derive(Debug, Clone)]
pub struct PhaseSkew {
    pub phase: &'static str,
    /// `coll_op` spans folded into this phase, all ranks.
    pub count: u64,
    pub total_ns: u64,
    /// Median / max of the per-rank totals.
    pub median_rank_ns: u64,
    pub max_rank_ns: u64,
    /// The rank holding the max.
    pub max_rank: i64,
    /// `max / median` (1.0 when balanced; grows with the straggler).
    pub skew: f64,
}

/// Rank phase totals → per-phase straggler ranking, worst skew first.
pub fn phase_skews(streams: &Streams) -> Vec<PhaseSkew> {
    let mut per: BTreeMap<&'static str, BTreeMap<i64, (u64, u64)>> = BTreeMap::new();
    for ev in &streams.events {
        if ev.kind != EventKind::CollOp {
            continue;
        }
        let entry = per
            .entry(phase_name(ev.step))
            .or_default()
            .entry(ev.rank)
            .or_insert((0, 0));
        entry.0 += 1;
        entry.1 += ev.dur_ns;
    }
    let mut out: Vec<PhaseSkew> = per
        .into_iter()
        .map(|(phase, ranks)| {
            let mut totals: Vec<(u64, i64)> =
                ranks.iter().map(|(&r, &(_, dur))| (dur, r)).collect();
            totals.sort_unstable();
            let median_rank_ns = totals[totals.len() / 2].0;
            let &(max_rank_ns, max_rank) = totals.last().expect("nonempty phase");
            PhaseSkew {
                phase,
                count: ranks.values().map(|&(c, _)| c).sum(),
                total_ns: ranks.values().map(|&(_, d)| d).sum(),
                median_rank_ns,
                max_rank_ns,
                max_rank,
                skew: if median_rank_ns > 0 {
                    max_rank_ns as f64 / median_rank_ns as f64
                } else if max_rank_ns > 0 {
                    f64::INFINITY
                } else {
                    1.0
                },
            }
        })
        .collect();
    out.sort_by(|a, b| b.skew.partial_cmp(&a.skew).unwrap_or(std::cmp::Ordering::Equal));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        kind: EventKind,
        rank: i64,
        peer: i64,
        at_ns: u64,
        dur_ns: u64,
        step: u64,
    ) -> CEvent {
        CEvent {
            t_ns: at_ns,
            dur_ns,
            at_ns,
            kind,
            rank,
            peer,
            ns: 8,
            epoch: 1,
            step,
            bytes: 64,
            transport: 0,
        }
    }

    #[test]
    fn matches_send_to_arrive_by_tag_and_peers() {
        let mut s = Streams::default();
        s.events.push(ev(EventKind::ChunkSend, 0, 1, 100, 0, 0));
        s.events.push(ev(EventKind::ChunkArrive, 1, 0, 150, 0, 0));
        // A second stream chunk on another hop must not cross-match.
        s.events.push(ev(EventKind::ChunkSend, 1, 2, 160, 0, 0));
        let g = match_edges(&s);
        assert_eq!(g.edges.len(), 1);
        assert_eq!((g.edges[0].from, g.edges[0].to), (0, 1));
        assert_eq!(g.edges[0].latency_ns, 50);
        assert_eq!(g.unmatched_sends, 1);
        assert_eq!(g.unmatched_arrives, 0);
    }

    #[test]
    fn edges_carry_the_transport_stamp() {
        let mut s = Streams::default();
        let mut snd = ev(EventKind::ChunkSend, 0, 1, 100, 0, 0);
        snd.transport = TransportKind::Tcp.code();
        // Only the send side is stamped (a truncated arrive line):
        // the edge still knows its wire.
        s.events.push(snd);
        s.events.push(ev(EventKind::ChunkArrive, 1, 0, 150, 0, 0));
        let g = match_edges(&s);
        assert_eq!(g.edges.len(), 1);
        assert_eq!(g.edges[0].transport, TransportKind::Tcp.code());
        assert_eq!(g.edges[0].transport_name(), "tcp");
    }

    #[test]
    fn negative_latency_becomes_skew_estimate() {
        let mut s = Streams::default();
        s.events.push(ev(EventKind::ChunkSend, 0, 1, 1000, 0, 0));
        s.events.push(ev(EventKind::ChunkArrive, 1, 0, 800, 0, 0));
        s.events.push(ev(EventKind::ChunkSend, 0, 1, 2000, 0, 1));
        s.events.push(ev(EventKind::ChunkArrive, 1, 0, 2050, 0, 1));
        let g = match_edges(&s);
        assert_eq!(g.skew_est_ns, 200);
        assert_eq!(g.min_latency_ns, 50);
        assert!(g.skew_exceeds_min_latency());
    }

    #[test]
    fn critical_path_covers_the_wall_span() {
        let mut s = Streams::default();
        // rank 0 computes 0..100, sends at 100; rank 1 idles, arrive
        // completes at 140, then computes 140..200.
        s.events.push(ev(EventKind::RemapExec, 0, -1, 0, 100, 0));
        s.events.push(ev(EventKind::ChunkSend, 0, 1, 100, 0, 0));
        s.events.push(ev(EventKind::ChunkArrive, 1, 0, 130, 10, 0));
        s.events.push(ev(EventKind::RemapExec, 1, -1, 140, 60, 0));
        let g = match_edges(&s);
        assert_eq!(g.edges.len(), 1);
        let cp = critical_path(&s, &g);
        assert_eq!(cp.total_ns(), 200);
        let covered: u64 = cp.segments.iter().map(|x| x.dur_ns()).sum();
        assert_eq!(covered, 200, "segments tile the wall span: {:#?}", cp.segments);
        // The wire hop is on the path.
        assert!(cp.segments.iter().any(|x| x.label == "wire"));
    }

    #[test]
    fn rank_times_partition_wall_into_busy_and_idle() {
        let mut s = Streams::default();
        s.events.push(ev(EventKind::CollOp, 0, -1, 0, 40, 0));
        s.events.push(ev(EventKind::CollOp, 0, -1, 20, 40, 0)); // overlaps
        s.events.push(ev(EventKind::Mark, 0, -1, 100, 0, 0));
        let rt = rank_times(&s);
        assert_eq!(rt.len(), 1);
        assert_eq!(rt[0].wall_ns(), 100);
        assert_eq!(rt[0].busy_ns, 60, "overlapping spans merge");
        assert_eq!(rt[0].busy_ns + rt[0].idle_ns(), rt[0].wall_ns());
    }

    #[test]
    fn straggler_ranking_names_the_slow_rank() {
        let mut s = Streams::default();
        for r in 0..4 {
            let dur = if r == 2 { 900 } else { 100 };
            // step = phase 5 << 16 (reduce_scatter).
            s.events.push(ev(EventKind::CollOp, r, -1, 0, dur, 5 << 16));
        }
        let skews = phase_skews(&s);
        assert_eq!(skews.len(), 1);
        assert_eq!(skews[0].phase, "reduce_scatter");
        assert_eq!(skews[0].max_rank, 2);
        assert!(skews[0].skew > 8.0, "skew {}", skews[0].skew);
    }

    #[test]
    fn empty_streams_never_panic() {
        let s = Streams::default();
        let g = match_edges(&s);
        let cp = critical_path(&s, &g);
        assert!(cp.segments.is_empty());
        assert_eq!(cp.total_ns(), 0);
        assert!(rank_times(&s).is_empty());
        assert!(phase_skews(&s).is_empty());
    }
}
