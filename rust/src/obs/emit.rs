//! Streaming NDJSON emission for trace events.
//!
//! One self-describing `trace_event_v1` object per line, formatted
//! into a reused buffer and written as events drain from the ring —
//! never a whole-document buffer. A stream opens with one
//! `trace_meta_v1` line carrying the rank and the wall-clock anchor
//! so per-process monotonic timestamps can be aligned in a merged
//! report.

use super::{
    current_rank, field_names, kind_name, metric_name, recorder, wall_anchor_ns, Event, EventKind,
    NO_PEER,
};
use crate::comm::{tags, TransportKind};
use std::fmt::Write as _;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Formats events as NDJSON lines into a reused buffer (no per-event
/// allocation in steady state).
#[derive(Default)]
pub struct NdjsonEmitter {
    line: String,
}

impl NdjsonEmitter {
    pub fn new() -> NdjsonEmitter {
        NdjsonEmitter { line: String::with_capacity(256) }
    }

    /// Format one event as a `trace_event_v1` line (no newline).
    pub fn event_line(&mut self, ev: &Event) -> &str {
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"schema\":\"trace_event_v1\",\"kind\":\"{}\",\"rank\":{},\"t_ns\":{},\"dur_ns\":{}",
            kind_name(ev.kind),
            ev.rank,
            ev.t_ns,
            ev.dur_ns
        );
        if ev.peer != NO_PEER {
            let _ = write!(self.line, ",\"peer\":{}", ev.peer);
        }
        if ev.kind == EventKind::Metric {
            let _ = write!(self.line, ",\"metric\":\"{}\",\"value\":{}", metric_name(ev.tag), ev.a);
        } else {
            if ev.tag != 0 {
                let (ns, epoch, step) = tags::unpack(ev.tag);
                let _ = write!(self.line, ",\"ns\":{ns},\"epoch\":{epoch},\"step\":{step}");
            }
            let (an, bn) = field_names(ev.kind);
            // Chunk events carry the sending transport's wire code in
            // the top byte of `b` (chunk indices need at most 16
            // bits). Surface it as a name and keep `chunk` clean;
            // code 0 means unstamped and the field is omitted.
            let b = if matches!(ev.kind, EventKind::ChunkSend | EventKind::ChunkArrive) {
                if let Some(k) = TransportKind::from_code((ev.b >> 56) as u8) {
                    let _ = write!(self.line, ",\"transport\":\"{}\"", k.name());
                }
                ev.b & 0x00FF_FFFF_FFFF_FFFF
            } else {
                ev.b
            };
            let _ = write!(self.line, ",\"{an}\":{},\"{bn}\":{b}", ev.a);
        }
        self.line.push('}');
        &self.line
    }
}

/// The stream-opening `trace_meta_v1` line for this process (no
/// newline).
pub fn meta_line() -> String {
    format!(
        "{{\"schema\":\"trace_meta_v1\",\"rank\":{},\"wall_anchor_ns\":{},\"proc\":{}}}",
        current_rank().map(|r| r as i64).unwrap_or(-1),
        wall_anchor_ns(),
        std::process::id()
    )
}

/// A closing `trace_meta_v1` line carrying the drop count, emitted by
/// [`close_sink`] so a reader knows whether the ring wrapped.
fn closing_line() -> String {
    format!(
        "{{\"schema\":\"trace_meta_v1\",\"rank\":{},\"dropped\":{},\"recorded\":{}}}",
        current_rank().map(|r| r as i64).unwrap_or(-1),
        recorder().dropped(),
        recorder().recorded()
    )
}

struct Sink {
    out: Box<dyn Write + Send>,
}

fn sink() -> &'static Mutex<Option<Sink>> {
    static SINK: OnceLock<Mutex<Option<Sink>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Open the process trace sink (`"-"` means stderr), writing the
/// meta line immediately. Replaces any previous sink.
pub fn install_sink(path: &str) -> std::io::Result<()> {
    let mut out: Box<dyn Write + Send> = if path == "-" {
        Box::new(std::io::stderr())
    } else {
        Box::new(std::io::BufWriter::new(std::fs::File::create(path)?))
    };
    writeln!(out, "{}", meta_line())?;
    *sink().lock().unwrap() = Some(Sink { out });
    Ok(())
}

/// Is a sink currently installed?
pub fn sink_installed() -> bool {
    sink().lock().unwrap().is_some()
}

/// Append one already-formatted line to the sink (no-op without one).
pub fn write_line(line: &str) {
    if let Some(s) = sink().lock().unwrap().as_mut() {
        let _ = writeln!(s.out, "{line}");
    }
}

/// Drain the global recorder, handing each event to `f` as one
/// formatted NDJSON line (no trailing newline). Returns the number of
/// events drained.
pub fn drain_events(mut f: impl FnMut(&str)) -> usize {
    let mut em = NdjsonEmitter::new();
    recorder().drain(|ev| f(em.event_line(&ev)))
}

/// Drain the global recorder into the installed sink.
pub fn flush_to_sink() -> usize {
    drain_events(write_line)
}

/// One `trace_hist_v1` line per non-empty runtime histogram
/// (cumulative totals — a later emission supersedes an earlier one).
fn hist_lines() -> Vec<String> {
    let rank = current_rank().map(|r| r as i64).unwrap_or(-1);
    super::hist::snapshots()
        .into_iter()
        .map(|(kind, snap)| snap.wire_line(rank, kind))
        .collect()
}

/// Render this process's pending telemetry as one NDJSON blob — the
/// worker→leader wire exchange: meta line, every drained event, the
/// runtime histograms, and the closing drop-count line. When a local
/// sink is installed the drained events are mirrored into it too, so
/// a spawned worker's own trace file and the leader's fold see the
/// same events.
pub fn render_pending() -> String {
    let mut out = meta_line();
    out.push('\n');
    let mirror = sink_installed();
    drain_events(|line| {
        out.push_str(line);
        out.push('\n');
        if mirror {
            write_line(line);
        }
    });
    for line in hist_lines() {
        out.push_str(&line);
        out.push('\n');
        if mirror {
            write_line(&line);
        }
    }
    out.push_str(&closing_line());
    out.push('\n');
    out
}

/// Final flush: drain remaining events, write the histogram and
/// closing meta lines, flush and drop the sink. Safe to call without
/// a sink.
pub fn close_sink() {
    flush_to_sink();
    for line in hist_lines() {
        write_line(&line);
    }
    write_line(&closing_line());
    if let Some(mut s) = sink().lock().unwrap().take() {
        let _ = s.out.flush();
    }
}

// ---------------------------------------------------------------------------
// Periodic metrics sampler
// ---------------------------------------------------------------------------

struct Sampler {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

fn sampler() -> &'static Mutex<Option<Sampler>> {
    static SAMPLER: OnceLock<Mutex<Option<Sampler>>> = OnceLock::new();
    SAMPLER.get_or_init(|| Mutex::new(None))
}

/// Record one round of counter samples (pool + datapath totals) into
/// the ring as [`EventKind::Metric`] events.
pub fn sample_metrics() {
    use super::metric;
    let (checkouts, hits) = crate::comm::datapath::pool_counters();
    let (ms, bs, mr, br) = crate::comm::datapath::comm_snapshot();
    for (id, v) in [
        (metric::POOL_CHECKOUTS, checkouts),
        (metric::POOL_HITS, hits),
        (metric::DP_MSGS_SENT, ms),
        (metric::DP_BYTES_SENT, bs),
        (metric::DP_MSGS_RECV, mr),
        (metric::DP_BYTES_RECV, br),
    ] {
        super::record(EventKind::Metric, id, NO_PEER, v, 0);
    }
}

/// Start the background metrics sampler: every `interval` it records
/// counter samples and flushes the ring to the sink. Idempotent
/// (restarts with the new interval).
pub fn start_metrics_sampler(interval: Duration) {
    stop_metrics_sampler();
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("obs-metrics".into())
        .spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                // Sleep in short steps so stop is prompt even for
                // second-scale intervals.
                let mut left = interval;
                while !flag.load(Ordering::Relaxed) && !left.is_zero() {
                    let step = left.min(Duration::from_millis(50));
                    std::thread::sleep(step);
                    left = left.saturating_sub(step);
                }
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                sample_metrics();
                flush_to_sink();
            }
        })
        .expect("spawn metrics sampler");
    *sampler().lock().unwrap() = Some(Sampler { stop, handle });
}

/// Stop the sampler (if running) and wait for it to exit.
pub fn stop_metrics_sampler() {
    let s = sampler().lock().unwrap().take();
    if let Some(s) = s {
        s.stop.store(true, Ordering::Relaxed);
        let _ = s.handle.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;

    #[test]
    fn event_lines_are_valid_self_describing_json() {
        let mut em = NdjsonEmitter::new();
        let ev = Event {
            t_ns: 42,
            dur_ns: 7,
            kind: EventKind::ChunkSend,
            rank: 3,
            peer: 1,
            tag: tags::pack(tags::NS_REMAP, 9, 2),
            a: 65552,
            b: 2,
        };
        let parsed = Json::parse(em.event_line(&ev)).expect("line parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("trace_event_v1"));
        assert_eq!(parsed.get("kind").unwrap().as_str(), Some("chunk_send"));
        assert_eq!(parsed.get("rank").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("peer").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("ns").unwrap().as_usize(), Some(tags::NS_REMAP as usize));
        assert_eq!(parsed.get("epoch").unwrap().as_usize(), Some(9));
        assert_eq!(parsed.get("step").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("bytes").unwrap().as_usize(), Some(65552));
        assert_eq!(parsed.get("chunk").unwrap().as_usize(), Some(2));
        assert!(parsed.get("transport").is_none(), "unstamped events omit the field");
    }

    #[test]
    fn chunk_events_surface_the_transport_stamp() {
        let mut em = NdjsonEmitter::new();
        let ev = Event {
            t_ns: 42,
            dur_ns: 0,
            kind: EventKind::ChunkArrive,
            rank: 1,
            peer: 0,
            tag: tags::pack(tags::NS_REMAP, 1, 0),
            a: 4096,
            b: 5 | ((TransportKind::Shmem.code() as u64) << 56),
        };
        let parsed = Json::parse(em.event_line(&ev)).expect("line parses");
        assert_eq!(parsed.get("transport").unwrap().as_str(), Some("shmem"));
        assert_eq!(parsed.get("chunk").unwrap().as_usize(), Some(5), "stamp masked out");
    }

    #[test]
    fn metric_lines_carry_name_and_value() {
        let mut em = NdjsonEmitter::new();
        let ev = Event {
            t_ns: 1,
            dur_ns: 0,
            kind: EventKind::Metric,
            rank: 0,
            peer: NO_PEER,
            tag: super::super::metric::POOL_HITS,
            a: 123,
            b: 0,
        };
        let parsed = Json::parse(em.event_line(&ev)).expect("line parses");
        assert_eq!(parsed.get("metric").unwrap().as_str(), Some("pool_hits"));
        assert_eq!(parsed.get("value").unwrap().as_usize(), Some(123));
        assert!(parsed.get("peer").is_none(), "NO_PEER is omitted");
    }

    #[test]
    fn meta_line_parses() {
        let parsed = Json::parse(&meta_line()).expect("meta parses");
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("trace_meta_v1"));
        assert!(parsed.get("wall_anchor_ns").unwrap().as_f64().is_some());
    }
}
