//! [`BackendRegistry`] — the constructed instances behind the
//! `--backend` axis.
//!
//! One registry per run: the leader builds it to validate the flag and
//! every process (leader and workers alike) builds its own from the
//! broadcast [`RunConfig`](crate::coordinator::RunConfig) — backends
//! hold process-local resources (thread pools, compiled artifacts)
//! that cannot travel over the wire.

use super::{Backend, BackendKind, ChunkedThreadedBackend, HostBackend, PjrtBackend};
use std::sync::Arc;

/// The set of constructed backends for one process.
pub struct BackendRegistry {
    entries: Vec<Arc<dyn Backend>>,
}

impl BackendRegistry {
    /// Construct one instance per [`BackendKind`]: host, threaded
    /// (`threads` pool width, 0 = one per online core), and PJRT over
    /// `artifacts_dir` (available only in `pjrt`-feature builds).
    pub fn with_defaults(threads: usize, artifacts_dir: &str) -> BackendRegistry {
        BackendRegistry {
            entries: vec![
                Arc::new(HostBackend::new()) as Arc<dyn Backend>,
                Arc::new(ChunkedThreadedBackend::new(threads)) as Arc<dyn Backend>,
                Arc::new(PjrtBackend::new(artifacts_dir)) as Arc<dyn Backend>,
            ],
        }
    }

    /// The registered backend for `kind` (the default registry covers
    /// every kind).
    pub fn get(&self, kind: BackendKind) -> Option<&Arc<dyn Backend>> {
        self.entries.iter().find(|b| b.kind() == kind)
    }

    /// Every registered backend, in registration order.
    pub fn backends(&self) -> &[Arc<dyn Backend>] {
        &self.entries
    }

    /// The backends that can actually execute in this build.
    pub fn available(&self) -> impl Iterator<Item = &Arc<dyn Backend>> {
        self.entries.iter().filter(|b| b.available())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_every_kind() {
        let reg = BackendRegistry::with_defaults(2, "artifacts");
        for kind in BackendKind::ALL {
            let be = reg.get(kind).expect("registered");
            assert_eq!(be.kind(), kind);
        }
        assert_eq!(reg.backends().len(), 3);
    }

    #[test]
    fn host_and_threaded_always_available() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        let avail: Vec<BackendKind> = reg.available().map(|b| b.kind()).collect();
        assert!(avail.contains(&BackendKind::Host));
        assert!(avail.contains(&BackendKind::Threaded));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_in_default_build() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        assert!(!reg.get(BackendKind::Pjrt).unwrap().available());
        assert_eq!(reg.available().count(), 2);
    }
}
