//! [`HostBackend`] — the crate's classic serial execution path moved
//! behind the [`Backend`] trait.
//!
//! Kernels are exactly [`crate::stream::ops`] (the `.loc`
//! performance-guarantee loops LLVM auto-vectorizes), and plan
//! execution is exactly the darray remap executor — so results are
//! bit-identical to the pre-backend code paths, which the
//! backend-equivalence property tests assert.

use super::{
    check_len, execute_plan_erased, expect_t, expect_t_mut, for_dtype, memcpy_erased, Backend,
    BackendKind, Result,
};
use crate::comm::Transport;
use crate::darray::RemapPlan;
use crate::dmap::Pid;
use crate::element::{Dtype, ElemSlice, ElemSliceMut, Element};
use crate::stream::ops;

/// Serial host loops (always available).
#[derive(Debug, Default)]
pub struct HostBackend;

impl HostBackend {
    pub fn new() -> HostBackend {
        HostBackend
    }
}

impl Backend for HostBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Host
    }

    fn prepare_alloc(&self, _dtype: Dtype, _len: usize) -> Result<()> {
        Ok(())
    }

    fn upload(&self, host: ElemSlice<'_>, dev: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(host, dev)
    }

    fn download(&self, dev: ElemSlice<'_>, host: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(dev, host)
    }

    fn copy(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            ops::copy(d, s);
            Ok(())
        })
    }

    fn scale(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            ops::scale(d, s, T::from_f64(q));
            Ok(())
        })
    }

    fn add(&self, a: ElemSlice<'_>, b: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sa = expect_t::<T>(a)?;
            let sb = expect_t::<T>(b)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sa.len())?;
            check_len(d.len(), sb.len())?;
            ops::add(d, sa, sb);
            Ok(())
        })
    }

    fn triad(
        &self,
        b: ElemSlice<'_>,
        c: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        q: f64,
    ) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sb = expect_t::<T>(b)?;
            let sc = expect_t::<T>(c)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sb.len())?;
            check_len(d.len(), sc.len())?;
            ops::triad(d, sb, sc, T::from_f64(q));
            Ok(())
        })
    }

    fn execute_plan(
        &self,
        plan: &RemapPlan,
        src: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        execute_plan_erased(plan, src, dst, pid, t, epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::super::BackendError;
    use super::*;

    #[test]
    fn kernels_match_definitions_every_dtype() {
        let be = HostBackend::new();
        let a = [1.0f64, 2.0, 3.0];
        let b = [10.0f64, 20.0, 30.0];
        let mut d = [0.0f64; 3];
        be.copy(f64::erase(&a), f64::erase_mut(&mut d)).unwrap();
        assert_eq!(d, a);
        be.scale(f64::erase(&a), f64::erase_mut(&mut d), 2.0).unwrap();
        assert_eq!(d, [2.0, 4.0, 6.0]);
        be.add(f64::erase(&a), f64::erase(&b), f64::erase_mut(&mut d))
            .unwrap();
        assert_eq!(d, [11.0, 22.0, 33.0]);
        be.triad(f64::erase(&b), f64::erase(&a), f64::erase_mut(&mut d), 0.5)
            .unwrap();
        assert_eq!(d, [10.5, 21.0, 31.5]);

        let ia = [1i64, 2];
        let mut id = [0i64; 2];
        be.triad(i64::erase(&ia), i64::erase(&ia), i64::erase_mut(&mut id), 3.0)
            .unwrap();
        assert_eq!(id, [4, 8]);

        let fa = [2.0f32, 4.0];
        let mut fd = [0.0f32; 2];
        be.scale(f32::erase(&fa), f32::erase_mut(&mut fd), 0.5).unwrap();
        assert_eq!(fd, [1.0, 2.0]);

        let ua = [u64::MAX, 1];
        let ub = [1u64, 1];
        let mut ud = [0u64; 2];
        be.add(u64::erase(&ua), u64::erase(&ub), u64::erase_mut(&mut ud))
            .unwrap();
        assert_eq!(ud, [0, 2]);
    }

    #[test]
    fn dtype_and_length_mismatches_are_errors() {
        let be = HostBackend::new();
        let a = [1.0f64; 4];
        let mut d32 = [0.0f32; 4];
        assert!(matches!(
            be.copy(f64::erase(&a), f32::erase_mut(&mut d32)),
            Err(BackendError::DtypeMismatch { .. })
        ));
        let mut d = [0.0f64; 3];
        assert!(matches!(
            be.copy(f64::erase(&a), f64::erase_mut(&mut d)),
            Err(BackendError::LenMismatch { .. })
        ));
    }
}
