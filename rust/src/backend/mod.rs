//! Pluggable execution backends — the paper's temporal-scaling seam.
//!
//! §IV's hardware table spans CPU cores, CPU nodes, and GPU nodes
//! across decades; the program stays the same because the
//! distributed-array model separates *what* is owner-computed from
//! *where* the owned bytes live and *which* engine streams them. This
//! module reifies that seam:
//!
//! * [`Backend`] — an object-safe executor: allocate/upload/download
//!   device buffers, run the four STREAM kernels, and execute a cached
//!   [`RemapPlan`](crate::darray::RemapPlan) transfer list. Methods
//!   speak the dtype-erased [`ElemSlice`]/[`ElemSliceMut`] views so a
//!   `&dyn Backend` covers every sealed [`Element`] dtype.
//! * [`DeviceBuffer`] — a typed handle to backend-owned storage
//!   ([`buffer`]).
//! * [`HostBackend`] — the crate's classic serial loops behind the
//!   trait ([`host`]).
//! * [`ChunkedThreadedBackend`] — an affinity-pinned worker pool
//!   (reusing [`crate::launcher::pinning`]) with kernels tiled over
//!   cache-sized chunks ([`chunked`]).
//! * [`PjrtBackend`] — routes kernels through the AOT PJRT artifacts
//!   ([`crate::runtime`]); reports [`BackendError::Unavailable`] in
//!   default (offline) builds exactly like the runtime stub ([`pjrt`]).
//! * [`BackendRegistry`] — the `--backend` axis: one constructed
//!   instance per [`BackendKind`] ([`registry`]).
//! * [`sched`] — the plan-driven scheduler mapping partition-local
//!   STREAM work onto any registered backend.
//!
//! Remap plans stay backend-agnostic index sets (see
//! `darray::engine`): the same cached plan drives host memcpys, pooled
//! copies, or staged device transfers through
//! [`Backend::execute_plan`], planning exactly once per
//! `(src_map, dst_map, shape)`.

pub mod buffer;
pub mod chunked;
pub mod host;
pub mod pjrt;
pub mod registry;
pub mod sched;

pub use buffer::DeviceBuffer;
pub use chunked::ChunkedThreadedBackend;
pub use host::HostBackend;
pub use pjrt::PjrtBackend;
pub use registry::BackendRegistry;
pub use sched::{run_stream_dtype, run_stream_spmd_t, run_stream_t, ReadyQueue};

use crate::comm::{CommError, Transport};
use crate::darray::RemapPlan;
use crate::dmap::Pid;
use crate::element::{Dtype, ElemSlice, ElemSliceMut};

/// Runtime identifier for a [`Backend`] — the `--backend` axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Serial host loops (the crate's classic execution path).
    Host,
    /// Affinity-pinned worker pool, kernels tiled over cache-sized
    /// chunks.
    Threaded,
    /// AOT PJRT artifacts (unavailable without the `pjrt` feature).
    Pjrt,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] =
        [BackendKind::Host, BackendKind::Threaded, BackendKind::Pjrt];

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "host" => Some(BackendKind::Host),
            "threaded" => Some(BackendKind::Threaded),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Threaded => "threaded",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// The valid `--backend` spellings, for one-line CLI errors.
    pub fn choices() -> &'static str {
        "host|threaded|pjrt"
    }

    /// Stable wire code (leader → worker config broadcast).
    pub fn code(&self) -> u8 {
        match self {
            BackendKind::Host => 0,
            BackendKind::Threaded => 1,
            BackendKind::Pjrt => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<BackendKind> {
        match c {
            0 => Some(BackendKind::Host),
            1 => Some(BackendKind::Threaded),
            2 => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Errors surfaced by backends.
#[derive(Debug)]
pub enum BackendError {
    /// The backend cannot execute in this build/environment (e.g. the
    /// PJRT backend without the `pjrt` feature + artifacts).
    Unavailable(BackendKind),
    /// The backend exists but cannot run this particular request.
    Unsupported { backend: BackendKind, what: String },
    /// An erased view held a different dtype than the call expected.
    DtypeMismatch { expected: Dtype, got: Dtype },
    /// Source/destination lengths disagree.
    LenMismatch { expected: usize, got: usize },
    /// A [`DeviceBuffer`] was used with a backend other than its
    /// allocator.
    WrongBackend { buffer: BackendKind, backend: BackendKind },
    /// The PJRT runtime failed underneath the backend.
    Runtime(String),
    /// Plan execution failed in the transport.
    Comm(CommError),
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Unavailable(k) => write!(
                f,
                "backend '{k}' is unavailable in this build/environment"
            ),
            BackendError::Unsupported { backend, what } => {
                write!(f, "backend '{backend}' does not support {what}")
            }
            BackendError::DtypeMismatch { expected, got } => {
                write!(f, "dtype mismatch: expected {expected}, got {got}")
            }
            BackendError::LenMismatch { expected, got } => {
                write!(f, "length mismatch: expected {expected}, got {got}")
            }
            BackendError::WrongBackend { buffer, backend } => write!(
                f,
                "buffer allocated on backend '{buffer}' used with backend '{backend}'"
            ),
            BackendError::Runtime(m) => write!(f, "runtime error: {m}"),
            BackendError::Comm(e) => write!(f, "communication failed: {e}"),
        }
    }
}

impl std::error::Error for BackendError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BackendError::Comm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CommError> for BackendError {
    fn from(e: CommError) -> Self {
        BackendError::Comm(e)
    }
}

pub type Result<T> = std::result::Result<T, BackendError>;

/// An execution backend: typed device buffers + the four STREAM
/// kernels + remap-plan execution, behind an object-safe interface.
///
/// All methods speak [`ElemSlice`]/[`ElemSliceMut`]; generic call
/// sites erase with [`crate::element::Element::erase`] (or go through
/// [`DeviceBuffer`] / [`sched`], which do it for them). Scalars cross
/// as `f64` and are narrowed with `Element::from_f64`, matching how
/// the CLI's single `q` parameterizes every dtype.
pub trait Backend: Send + Sync {
    /// Which axis value this backend implements.
    fn kind(&self) -> BackendKind;

    /// Can this backend execute in this build/environment?
    fn available(&self) -> bool {
        true
    }

    /// Capability gate run before a [`DeviceBuffer`] is created:
    /// checks availability and (for device backends) dtype support.
    fn prepare_alloc(&self, dtype: Dtype, len: usize) -> Result<()>;

    /// Host → device copy. Both views must hold the same dtype/length.
    fn upload(&self, host: ElemSlice<'_>, dev: ElemSliceMut<'_>) -> Result<()>;

    /// Device → host copy.
    fn download(&self, dev: ElemSlice<'_>, host: ElemSliceMut<'_>) -> Result<()>;

    /// STREAM Copy: `dst[i] = src[i]`.
    fn copy(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()>;

    /// STREAM Scale: `dst[i] = q · src[i]`.
    fn scale(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64) -> Result<()>;

    /// STREAM Add: `dst[i] = a[i] + b[i]`.
    fn add(&self, a: ElemSlice<'_>, b: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()>;

    /// STREAM Triad: `dst[i] = b[i] + q · c[i]`.
    fn triad(&self, b: ElemSlice<'_>, c: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64)
        -> Result<()>;

    /// Execute a prebuilt remap plan's transfer list for one PID:
    /// local pieces move within this backend's buffers, remote pieces
    /// travel over `t`. The plan is a backend-agnostic index set — the
    /// same cached [`RemapPlan`] drives every backend.
    fn execute_plan(
        &self,
        plan: &RemapPlan,
        src: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()>;
}

/// Dispatch a dtype token to a monomorphic body: `$T` is aliased to
/// the concrete sealed type inside `$body`.
macro_rules! for_dtype {
    ($dt:expr, $T:ident, $body:block) => {
        match $dt {
            $crate::element::Dtype::F32 => {
                type $T = f32;
                $body
            }
            $crate::element::Dtype::F64 => {
                type $T = f64;
                $body
            }
            $crate::element::Dtype::I64 => {
                type $T = i64;
                $body
            }
            $crate::element::Dtype::U64 => {
                type $T = u64;
                $body
            }
        }
    };
}
pub(crate) use for_dtype;

/// Recover a typed slice from an erased view or report the mismatch.
pub(crate) fn expect_t<T: crate::element::Element>(s: ElemSlice<'_>) -> Result<&[T]> {
    let got = s.dtype();
    T::unerase(s).ok_or(BackendError::DtypeMismatch { expected: T::DTYPE, got })
}

/// Mutable counterpart of [`expect_t`].
pub(crate) fn expect_t_mut<T: crate::element::Element>(s: ElemSliceMut<'_>) -> Result<&mut [T]> {
    let got = s.dtype();
    T::unerase_mut(s).ok_or(BackendError::DtypeMismatch { expected: T::DTYPE, got })
}

/// Equal-length guard shared by every kernel implementation.
pub(crate) fn check_len(expected: usize, got: usize) -> Result<()> {
    if expected != got {
        return Err(BackendError::LenMismatch { expected, got });
    }
    Ok(())
}

/// Host-visible memcpy between two erased views of the same dtype —
/// the upload/download implementation every host-backed backend
/// shares (one definition, three backends).
pub(crate) fn memcpy_erased(src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
    for_dtype!(dst.dtype(), T, {
        let s = expect_t::<T>(src)?;
        let d = expect_t_mut::<T>(dst)?;
        check_len(d.len(), s.len())?;
        d.copy_from_slice(s);
        Ok(())
    })
}

/// Erased wrapper over
/// [`execute_plan_typed`](crate::darray::engine::execute_plan_typed) —
/// the serial coalesced plan execution the host and pjrt backends
/// share (the chunked backend reuses the same per-peer message layout
/// but packs/unpacks large payloads with its pinned pool).
pub(crate) fn execute_plan_erased(
    plan: &RemapPlan,
    src: ElemSlice<'_>,
    dst: ElemSliceMut<'_>,
    pid: Pid,
    t: &dyn Transport,
    epoch: u64,
) -> Result<()> {
    for_dtype!(dst.dtype(), T, {
        let s = expect_t::<T>(src)?;
        let d = expect_t_mut::<T>(dst)?;
        crate::darray::engine::execute_plan_typed::<T>(plan, s, d, pid, t, epoch)?;
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_name_code_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
            assert_eq!(BackendKind::from_code(k.code()), Some(k));
        }
        assert_eq!(BackendKind::parse("cuda"), None);
        assert_eq!(BackendKind::from_code(7), None);
        assert_eq!(BackendKind::choices(), "host|threaded|pjrt");
    }

    #[test]
    fn errors_render_one_line() {
        let msgs = [
            BackendError::Unavailable(BackendKind::Pjrt).to_string(),
            BackendError::DtypeMismatch {
                expected: crate::element::Dtype::F64,
                got: crate::element::Dtype::F32,
            }
            .to_string(),
            BackendError::LenMismatch { expected: 4, got: 5 }.to_string(),
            BackendError::WrongBackend {
                buffer: BackendKind::Host,
                backend: BackendKind::Threaded,
            }
            .to_string(),
        ];
        for m in msgs {
            assert!(!m.is_empty() && !m.contains('\n'));
        }
    }

    #[test]
    fn expect_helpers_enforce_dtype() {
        let v = [1.0f64, 2.0];
        let e = <f64 as crate::element::Element>::erase(&v);
        assert!(expect_t::<f64>(e).is_ok());
        assert!(matches!(
            expect_t::<f32>(e),
            Err(BackendError::DtypeMismatch { .. })
        ));
        assert!(check_len(3, 3).is_ok());
        assert!(matches!(check_len(3, 4), Err(BackendError::LenMismatch { .. })));
    }
}
