//! [`DeviceBuffer`] — a typed handle to backend-owned storage.
//!
//! The buffer models the paper's §IV hardware axis: the *program*
//! holds a typed handle and moves data with explicit upload/download;
//! *where* the bytes live is the backend's business. The host-class
//! backends back it with ordinary host memory (upload/download are
//! memcpys), and the PJRT backend treats it as the host staging mirror
//! of device memory — each kernel stages through the artifact exactly
//! like the engine-level PJRT path does. Either way the discipline is
//! identical, so code written against [`DeviceBuffer`] is
//! backend-portable by construction.

use super::{Backend, BackendError, BackendKind, Result};
use crate::element::{Dtype, ElemSlice, ElemSliceMut, Element};

/// Typed storage allocated by (and tied to) one [`Backend`].
#[derive(Debug, Clone)]
pub struct DeviceBuffer<T: Element> {
    kind: BackendKind,
    data: Vec<T>,
}

impl<T: Element> DeviceBuffer<T> {
    /// Allocate a zero-filled buffer of `len` elements on `backend`.
    pub fn alloc(backend: &dyn Backend, len: usize) -> Result<DeviceBuffer<T>> {
        backend.prepare_alloc(T::DTYPE, len)?;
        Ok(DeviceBuffer { kind: backend.kind(), data: vec![T::ZERO; len] })
    }

    /// Which backend allocated this buffer.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    pub fn dtype(&self) -> Dtype {
        T::DTYPE
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Erased immutable view (kernel source operand).
    pub fn view(&self) -> ElemSlice<'_> {
        T::erase(&self.data)
    }

    /// Erased mutable view (kernel destination operand).
    pub fn view_mut(&mut self) -> ElemSliceMut<'_> {
        T::erase_mut(&mut self.data)
    }

    /// Copy `host` into the buffer through the owning backend.
    pub fn upload_from(&mut self, backend: &dyn Backend, host: &[T]) -> Result<()> {
        self.check_backend(backend)?;
        super::check_len(self.data.len(), host.len())?;
        backend.upload(T::erase(host), T::erase_mut(&mut self.data))
    }

    /// Copy the buffer into `host` through the owning backend.
    pub fn download_into(&self, backend: &dyn Backend, host: &mut [T]) -> Result<()> {
        self.check_backend(backend)?;
        super::check_len(self.data.len(), host.len())?;
        backend.download(T::erase(&self.data), T::erase_mut(host))
    }

    fn check_backend(&self, backend: &dyn Backend) -> Result<()> {
        if backend.kind() != self.kind {
            return Err(BackendError::WrongBackend {
                buffer: self.kind,
                backend: backend.kind(),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ChunkedThreadedBackend, HostBackend};
    use super::*;

    #[test]
    fn alloc_upload_download_roundtrip() {
        let be = HostBackend::new();
        let host: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let mut buf = DeviceBuffer::<f32>::alloc(&be, 100).unwrap();
        assert_eq!(buf.len(), 100);
        assert_eq!(buf.dtype(), Dtype::F32);
        assert_eq!(buf.kind(), BackendKind::Host);
        buf.upload_from(&be, &host).unwrap();
        let mut back = vec![0.0f32; 100];
        buf.download_into(&be, &mut back).unwrap();
        assert_eq!(back, host);
    }

    #[test]
    fn wrong_backend_refused() {
        let host = HostBackend::new();
        let threaded = ChunkedThreadedBackend::new(2);
        let mut buf = DeviceBuffer::<f64>::alloc(&host, 8).unwrap();
        let data = [1.0f64; 8];
        assert!(matches!(
            buf.upload_from(&threaded, &data),
            Err(BackendError::WrongBackend { .. })
        ));
    }

    #[test]
    fn length_mismatch_refused() {
        let be = HostBackend::new();
        let mut buf = DeviceBuffer::<u64>::alloc(&be, 4).unwrap();
        assert!(matches!(
            buf.upload_from(&be, &[1u64; 5]),
            Err(BackendError::LenMismatch { .. })
        ));
        let mut small = [0u64; 3];
        assert!(matches!(
            buf.download_into(&be, &mut small),
            Err(BackendError::LenMismatch { .. })
        ));
    }

    #[test]
    fn empty_buffer_ok() {
        let be = HostBackend::new();
        let buf = DeviceBuffer::<i64>::alloc(&be, 0).unwrap();
        assert!(buf.is_empty());
        assert_eq!(buf.view().len(), 0);
    }
}
