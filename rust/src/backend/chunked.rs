//! [`ChunkedThreadedBackend`] — kernels tiled over cache-sized chunks
//! and fanned across an affinity-pinned worker pool.
//!
//! The §V thread axis as a backend: each kernel splits the vector into
//! one contiguous range per pool thread (contiguous, not interleaved,
//! to preserve streaming access — the same reason the paper pins
//! threads to adjacent cores), and each thread walks its range in
//! cache-sized tiles so a tile's working set stays resident between
//! the load and the store. The pool is a pinned
//! [`OpPool`](crate::stream::threaded::OpPool): spawned threads pin to
//! the adjacent cores of *this process's* launcher window
//! (`slot · Ntpn + tid`, from the `DISTARRAY_*` environment; base 0
//! for the leader and in-process runs), gracefully skipped when the
//! plan exceeds the machine.
//!
//! Element-wise determinism: tiling and threading change *which core*
//! computes an element, never the arithmetic, so results are
//! bit-identical to [`super::HostBackend`] — asserted by the
//! backend-equivalence property tests.
//!
//! Remap execution reuses the engine's coalesced per-peer message
//! layout, but packs and unpacks payloads at least one tile large
//! with the pinned pool (see `execute_plan`) — the wire bytes are
//! identical to the serial path, only the cores doing the memcpys
//! differ.

use super::sched::ReadyQueue;
use super::{
    check_len, expect_t, expect_t_mut, for_dtype, memcpy_erased, Backend, BackendKind, Result,
};
use crate::comm::datapath::{self, ArrivedChunk, ChunkStream, ChunkTag};
use crate::comm::{CommError, Transport, WireWriter};
use crate::darray::engine::{
    check_group_payload, recv_groups, remap_tag, scatter_payload_bytes, send_group_typed,
    unpack_group_typed, write_group_header, GroupScatter, PeerGroup,
};
use crate::darray::RemapPlan;
use crate::dmap::{GlobalRange, Pid};
use crate::element::{Dtype, ElemSlice, ElemSliceMut, Element};
use crate::stream::ops;
use crate::stream::threaded::{chunk_bounds, OpPool};
use std::sync::OnceLock;

/// In-flight chunks the overlapped receive path buffers between the
/// drain (producer) and the unpack thread (consumer): enough to ride
/// out scheduling jitter, small enough that memory stays bounded at
/// `depth × chunk_bytes` per remap.
const OVERLAP_QUEUE_DEPTH: usize = 8;

/// Default tile: 256 KiB — comfortably inside a per-core L2 while
/// large enough that loop overhead vanishes against memory traffic.
pub const DEFAULT_TILE_BYTES: usize = 256 * 1024;

/// First core of this process's launcher window: `slot × Ntpn` from
/// the `DISTARRAY_*` worker environment, 0 for the leader and for
/// in-process (test/SPMD) use. Keeps every process's pool inside its
/// own adjacent-core window instead of stacking all pools on core 0.
fn process_base_core() -> usize {
    let get = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<usize>().ok());
    match (get("DISTARRAY_SLOT"), get("DISTARRAY_NTPN")) {
        (Some(slot), Some(ntpn)) => slot * ntpn,
        _ => 0,
    }
}

/// Rebuild an immutable slice from an address smuggled across a
/// `'static` job closure as `usize`.
///
/// SAFETY (caller's obligations): `addr` must come from a live slice
/// of `T` with at least `i + len` elements that outlives the pool's
/// blocking `run` call, and `[i, i+len)` must be disjoint from every
/// range any thread mutates during that call.
unsafe fn slice_at<'a, T>(addr: usize, i: usize, len: usize) -> &'a [T] {
    std::slice::from_raw_parts((addr as *const T).add(i), len)
}

/// Mutable counterpart of [`slice_at`]; additionally requires that no
/// other thread touches `[i, i+len)` at all during the call.
unsafe fn slice_at_mut<'a, T>(addr: usize, i: usize, len: usize) -> &'a mut [T] {
    std::slice::from_raw_parts_mut((addr as *mut T).add(i), len)
}

/// Walk `[lo, hi)` in `tile`-element steps.
macro_rules! tiled {
    ($lo:expr, $hi:expr, $tile:expr, |$i:ident, $j:ident| $body:expr) => {{
        let mut $i = $lo;
        while $i < $hi {
            let $j = ($i + $tile).min($hi);
            $body;
            $i = $j;
        }
    }};
}

/// Affinity-pinned chunk-parallel backend.
pub struct ChunkedThreadedBackend {
    threads: usize,
    tile_bytes: usize,
    /// Double-buffer multi-chunk receives (compute on arrival)?
    /// Defaults on; [`ChunkedThreadedBackend::with_overlap`] turns it
    /// off — the bench's serial comparator and an escape hatch.
    overlap: bool,
    /// Lazily spawned: constructing the backend (e.g. in a registry)
    /// costs nothing until a kernel actually runs.
    pool: OnceLock<OpPool>,
}

impl ChunkedThreadedBackend {
    /// `threads == 0` means auto (one per online core).
    pub fn new(threads: usize) -> ChunkedThreadedBackend {
        ChunkedThreadedBackend::with_tile(threads, DEFAULT_TILE_BYTES)
    }

    /// Explicit cache-tile size in bytes (floored to one element).
    pub fn with_tile(threads: usize, tile_bytes: usize) -> ChunkedThreadedBackend {
        let threads = if threads == 0 {
            crate::launcher::pinning::online_cores()
        } else {
            threads
        };
        ChunkedThreadedBackend {
            threads,
            tile_bytes: tile_bytes.max(8),
            overlap: true,
            pool: OnceLock::new(),
        }
    }

    /// Enable/disable the overlapped (double-buffered) receive path.
    /// Off, every remap receive reassembles whole messages before
    /// unpacking — the serial reference the equivalence tests and the
    /// overlap bench compare against.
    pub fn with_overlap(mut self, overlap: bool) -> ChunkedThreadedBackend {
        self.overlap = overlap;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn pool(&self) -> &OpPool {
        self.pool
            .get_or_init(|| OpPool::pinned(self.threads, process_base_core()))
    }

    fn tile_elems<T: Element>(&self) -> usize {
        (self.tile_bytes / T::WIDTH).max(1)
    }
}

impl Backend for ChunkedThreadedBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Threaded
    }

    fn prepare_alloc(&self, _dtype: Dtype, _len: usize) -> Result<()> {
        Ok(())
    }

    fn upload(&self, host: ElemSlice<'_>, dev: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(host, dev)
    }

    fn download(&self, dev: ElemSlice<'_>, host: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(dev, host)
    }

    fn copy(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            let (sp, dp, n) = (s.as_ptr() as usize, d.as_mut_ptr() as usize, d.len());
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: per-tid chunks are disjoint subranges of
                    // slices that outlive this blocking `run` call.
                    let (sv, dv) = unsafe {
                        (slice_at::<T>(sp, i, j - i), slice_at_mut::<T>(dp, i, j - i))
                    };
                    ops::copy(dv, sv)
                });
            });
            Ok(())
        })
    }

    fn scale(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            let q = T::from_f64(q);
            let (sp, dp, n) = (s.as_ptr() as usize, d.as_mut_ptr() as usize, d.len());
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (sv, dv) = unsafe {
                        (slice_at::<T>(sp, i, j - i), slice_at_mut::<T>(dp, i, j - i))
                    };
                    ops::scale(dv, sv, q)
                });
            });
            Ok(())
        })
    }

    fn add(&self, a: ElemSlice<'_>, b: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sa = expect_t::<T>(a)?;
            let sb = expect_t::<T>(b)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sa.len())?;
            check_len(d.len(), sb.len())?;
            let (ap, bp, dp, n) = (
                sa.as_ptr() as usize,
                sb.as_ptr() as usize,
                d.as_mut_ptr() as usize,
                d.len(),
            );
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (av, bv, dv) = unsafe {
                        (
                            slice_at::<T>(ap, i, j - i),
                            slice_at::<T>(bp, i, j - i),
                            slice_at_mut::<T>(dp, i, j - i),
                        )
                    };
                    ops::add(dv, av, bv)
                });
            });
            Ok(())
        })
    }

    fn triad(
        &self,
        b: ElemSlice<'_>,
        c: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        q: f64,
    ) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sb = expect_t::<T>(b)?;
            let sc = expect_t::<T>(c)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sb.len())?;
            check_len(d.len(), sc.len())?;
            let q = T::from_f64(q);
            let (bp, cp, dp, n) = (
                sb.as_ptr() as usize,
                sc.as_ptr() as usize,
                d.as_mut_ptr() as usize,
                d.len(),
            );
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (bv, cv, dv) = unsafe {
                        (
                            slice_at::<T>(bp, i, j - i),
                            slice_at::<T>(cp, i, j - i),
                            slice_at_mut::<T>(dp, i, j - i),
                        )
                    };
                    ops::triad(dv, bv, cv, q)
                });
            });
            Ok(())
        })
    }

    /// Coalesced plan execution with **pool-parallel pack/unpack**:
    /// the per-peer message layout is identical to the serial engine
    /// routine (same header, same packed payload, same tags — so
    /// chunked and host endpoints interoperate within one remap), but
    /// payloads at least one cache tile large are gathered into the
    /// pooled wire buffer and scattered out of received messages by
    /// the pinned worker pool, chunked over payload elements so uneven
    /// range lists still balance. Sub-tile payloads and big-endian
    /// targets take the serial engine path unchanged.
    fn execute_plan(
        &self,
        plan: &RemapPlan,
        src: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            self.execute_plan_chunked::<T>(plan, s, d, pid, t, epoch)
        })
    }
}

impl ChunkedThreadedBackend {
    /// Is this group's payload worth fanning out over the pool?
    fn parallel_payload<T: Element>(&self, g: &PeerGroup) -> bool {
        cfg!(target_endian = "little") && self.threads > 1 && g.total * T::WIDTH >= self.tile_bytes
    }

    fn execute_plan_chunked<T: Element>(
        &self,
        plan: &RemapPlan,
        src: &[T],
        dst: &mut [T],
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        if plan.is_aligned() {
            check_len(dst.len(), src.len())?;
            dst.copy_from_slice(src);
            return Ok(());
        }
        let tag = remap_tag(epoch);
        for &(s_off, d_off, len) in plan.local_copies(pid) {
            dst[d_off..d_off + len].copy_from_slice(&src[s_off..s_off + len]);
        }
        for g in plan.peer_sends(pid) {
            if self.parallel_payload::<T>(g) {
                self.send_group_par::<T>(g, src, t, tag)?;
            } else {
                send_group_typed::<T>(g, src, t, tag)?;
            }
        }
        // Multi-chunk incoming streams are consumed on arrival: the
        // drain thread receives chunk k while the unpack thread
        // scatters chunk k − 1. Single-chunk (sub-chunk-size) streams
        // gain nothing from a second thread — they stay on the
        // reassembling path, as do big-endian targets and explicit
        // `with_overlap(false)` backends.
        let multi_chunk = plan
            .peer_recvs(pid)
            .iter()
            .any(|g| g.header_bytes() + 9 + g.total * T::WIDTH > datapath::ambient_chunk_bytes());
        if self.overlap && cfg!(target_endian = "little") && multi_chunk {
            self.recv_groups_overlapped::<T>(plan, pid, t, tag, dst)?;
        } else {
            recv_groups(plan, pid, t, tag, |g, payload| {
                if self.parallel_payload::<T>(g) {
                    self.unpack_group_par::<T>(g, &payload, dst)
                } else {
                    unpack_group_typed::<T>(g, &payload, dst)
                }
            })?;
        }
        Ok(())
    }

    /// Double-buffered receive: the calling thread runs the chunk-
    /// granular drain and pushes each landed [`ArrivedChunk`] into a
    /// bounded [`ReadyQueue`]; a scoped consumer thread pops and
    /// scatters each chunk straight into `dst` (pool-parallel for
    /// tile-sized windows, serial otherwise). Wire time and unpack
    /// time overlap instead of adding; wire bytes and destination
    /// contents are bit-identical to the serial path.
    fn recv_groups_overlapped<T: Element>(
        &self,
        plan: &RemapPlan,
        pid: Pid,
        t: &dyn Transport,
        tag: ChunkTag,
        dst: &mut [T],
    ) -> crate::comm::Result<()> {
        let groups = plan.peer_recvs(pid);
        for g in groups {
            assert!(
                g.local_extent <= dst.len(),
                "remap plan/slice mismatch: group writes {} destination elements, slice has {}",
                g.local_extent,
                dst.len()
            );
        }
        let peers: Vec<Pid> = groups.iter().map(|g| g.peer).collect();
        let queue = ReadyQueue::<ArrivedChunk>::new(OVERLAP_QUEUE_DEPTH);
        let consumer_stopped = std::cell::Cell::new(false);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| {
                let mut scatters: Vec<GroupScatter<'_, T>> =
                    groups.iter().map(GroupScatter::new).collect();
                let mut res: crate::comm::Result<()> = Ok(());
                while let Some(c) = queue.pop() {
                    match scatters[c.peer_idx].feed_raw(c.payload()) {
                        Ok(None) => {}
                        Ok(Some((off, win))) => {
                            let t0 = crate::obs::span_begin();
                            let g = &groups[c.peer_idx];
                            if win.len() >= self.tile_bytes && self.parallel_payload::<T>(g) {
                                self.scatter_window_par::<T>(g, off, win, dst);
                            } else {
                                scatter_payload_bytes::<T>(g, off, win, dst);
                            }
                            crate::obs_span!(
                                crate::obs::EventKind::ScatterWindow,
                                t0,
                                tag: tag.at(c.chunk_idx as u64),
                                peer: c.peer as u32,
                                a: win.len() as u64,
                                b: off as u64
                            );
                        }
                        Err(e) => {
                            res = Err(e);
                            break;
                        }
                    }
                }
                // Unblocks a producer stuck on a full queue when we
                // bailed early; harmless after a normal drain.
                queue.close();
                if res.is_ok() {
                    res = scatters.iter().try_for_each(GroupScatter::finish);
                }
                res
            });
            let prod = ChunkStream::drain_chunks(t, &peers, tag, |c| {
                if queue.push(c) {
                    Ok(())
                } else {
                    consumer_stopped.set(true);
                    Err(CommError::Malformed("overlapped unpack consumer stopped".into()))
                }
            });
            queue.close();
            let cons = consumer.join().expect("overlap unpack thread panicked");
            // When the drain failed only because the consumer bailed,
            // the consumer's error is the root cause.
            if consumer_stopped.get() {
                cons.and(prod)
            } else {
                prod.and(cons)
            }
        })
    }

    /// Pool-parallel scatter of one landed chunk's payload window:
    /// split-element edge bytes (a window boundary can bisect an
    /// element) go serially, the whole-element body fans out over the
    /// pinned pool — the gang that unpacks chunk k − 1 while chunk k
    /// rides the wire.
    fn scatter_window_par<T: Element>(
        &self,
        g: &PeerGroup,
        byte_off: usize,
        win: &[u8],
        dst: &mut [T],
    ) {
        let width = T::WIDTH;
        let head = ((width - byte_off % width) % width).min(win.len());
        if head > 0 {
            scatter_payload_bytes::<T>(g, byte_off, &win[..head], dst);
        }
        let body = (win.len() - head) / width * width;
        if body > 0 {
            self.run_payload_copy::<T>(
                g,
                dst.as_mut_ptr() as usize,
                win[head..].as_ptr() as usize,
                CopyDir::Unpack,
                (byte_off + head) / width,
                body / width,
            );
        }
        if head + body < win.len() {
            scatter_payload_bytes::<T>(g, byte_off + head + body, &win[head + body..], dst);
        }
    }

    /// Pack one coalesced message with the pinned pool: the payload
    /// region of the pooled wire buffer is filled by all threads in
    /// parallel, each copying a contiguous span of payload *elements*
    /// (split mid-range when ranges are uneven).
    fn send_group_par<T: Element>(
        &self,
        g: &PeerGroup,
        src: &[T],
        t: &dyn Transport,
        tag: ChunkTag,
    ) -> crate::comm::Result<()> {
        assert!(
            g.local_extent <= src.len(),
            "remap plan/slice mismatch: group reads {} source elements, slice has {}",
            g.local_extent,
            src.len()
        );
        let mut header = datapath::checkout(g.header_bytes());
        let mut w = WireWriter::from_vec(header.take());
        write_group_header(&mut w, g);
        header.restore(w.finish());

        // Payload part: the typed-slice prefix, then the packed bytes,
        // written in place by the gang (no zero-fill pass — the
        // group's prefix sums tile the byte range exactly).
        let nbytes = g.total * T::WIDTH;
        let mut payload = datapath::checkout(9 + nbytes);
        let mut pw = WireWriter::from_vec(payload.take());
        pw.put_u64(g.total as u64);
        pw.put_u8(T::DTYPE.code());
        let mut buf = pw.finish();
        let prefix = buf.len();
        buf.reserve(nbytes);
        // SAFETY: capacity was just reserved, u8 needs no drop/init
        // tracking, and `run_payload_copy` below writes every byte of
        // `[prefix, prefix + nbytes)` before anyone reads the buffer.
        unsafe { buf.set_len(prefix + nbytes) };
        payload.restore(buf);
        let pay_addr = payload.as_mut_ptr() as usize + prefix;
        self.run_payload_copy::<T>(g, src.as_ptr() as usize, pay_addr, CopyDir::Pack, 0, g.total);
        ChunkStream::send(
            t,
            g.peer,
            tag,
            datapath::ambient_chunk_bytes(),
            &[header.as_slice(), payload.as_slice()],
        )?;
        Ok(())
    }

    /// Scatter one received coalesced message into `dst` with the
    /// pinned pool (after serial header validation).
    fn unpack_group_par<T: Element>(
        &self,
        g: &PeerGroup,
        payload: &[u8],
        dst: &mut [T],
    ) -> crate::comm::Result<()> {
        assert!(
            g.local_extent <= dst.len(),
            "remap plan/slice mismatch: group writes {} destination elements, slice has {}",
            g.local_extent,
            dst.len()
        );
        let bytes = check_group_payload::<T>(g, payload)?;
        self.run_payload_copy::<T>(
            g,
            dst.as_mut_ptr() as usize,
            bytes.as_ptr() as usize,
            CopyDir::Unpack,
            0,
            g.total,
        );
        Ok(())
    }

    /// The shared gang kernel behind parallel pack and unpack: copy
    /// between the local slice (`local_addr`, indexed by the group's
    /// `local_offsets`) and packed payload bytes (`payload_addr`,
    /// which points at the packed bytes of element `base`), chunking
    /// the `span`-element payload window `[base, base + span)` evenly
    /// across threads. Whole-message callers pass `(0, g.total)`; the
    /// overlapped receive passes one landed chunk's element window.
    fn run_payload_copy<T: Element>(
        &self,
        g: &PeerGroup,
        local_addr: usize,
        payload_addr: usize,
        dir: CopyDir,
        base: usize,
        span: usize,
    ) {
        let threads = self.threads;
        let n_segs = g.ranges.len();
        let ranges_addr = g.ranges.as_ptr() as usize;
        let loffs_addr = g.local_offsets.as_ptr() as usize;
        let poffs_addr = g.payload_offsets.as_ptr() as usize;
        let width = T::WIDTH;
        self.pool().run(move |tid| {
            let (lo, hi) = chunk_bounds(threads, span, tid);
            if lo >= hi {
                return;
            }
            let (mut pos, ehi) = (base + lo, base + hi);
            // SAFETY: the group's vectors and both buffers outlive the
            // pool's blocking `run` call; per-tid payload spans are
            // disjoint, and the local-side ranges they touch are the
            // disjoint plan ranges of this single group.
            let (ranges, loffs, poffs) = unsafe {
                (
                    slice_at::<GlobalRange>(ranges_addr, 0, n_segs),
                    slice_at::<usize>(loffs_addr, 0, n_segs),
                    slice_at::<usize>(poffs_addr, 0, n_segs),
                )
            };
            let mut k = poffs.partition_point(|&p| p <= pos) - 1;
            while pos < ehi {
                let within = pos - poffs[k];
                let n = (ranges[k].len() - within).min(ehi - pos);
                let local = (loffs[k] + within) * width;
                let packed = (pos - base) * width;
                // SAFETY: in-bounds per the plan's offset tables; on a
                // little-endian target (checked by the caller) raw
                // element bytes ARE the wire encoding.
                unsafe {
                    match dir {
                        CopyDir::Pack => std::ptr::copy_nonoverlapping(
                            (local_addr as *const u8).add(local),
                            (payload_addr as *mut u8).add(packed),
                            n * width,
                        ),
                        CopyDir::Unpack => std::ptr::copy_nonoverlapping(
                            (payload_addr as *const u8).add(packed),
                            (local_addr as *mut u8).add(local),
                            n * width,
                        ),
                    }
                }
                pos += n;
                k += 1;
            }
        });
    }
}

/// Direction of [`ChunkedThreadedBackend::run_payload_copy`].
#[derive(Clone, Copy)]
enum CopyDir {
    Pack,
    Unpack,
}

#[cfg(test)]
mod tests {
    use super::super::HostBackend;
    use super::*;

    #[test]
    fn threaded_matches_host_bitwise() {
        let host = HostBackend::new();
        // A tiny tile so even a small vector crosses tile boundaries,
        // and more threads than divide n evenly.
        let th = ChunkedThreadedBackend::with_tile(3, 64);
        let n = 1013;
        let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 7.0).collect();
        let b: Vec<f64> = (0..n).map(|i| (i * i % 97) as f64).collect();
        let q = 0.414;

        let mut dh = vec![0.0f64; n];
        let mut dt = vec![0.0f64; n];
        host.scale(f64::erase(&a), f64::erase_mut(&mut dh), q).unwrap();
        th.scale(f64::erase(&a), f64::erase_mut(&mut dt), q).unwrap();
        assert_eq!(dh, dt);

        host.add(f64::erase(&a), f64::erase(&b), f64::erase_mut(&mut dh))
            .unwrap();
        th.add(f64::erase(&a), f64::erase(&b), f64::erase_mut(&mut dt))
            .unwrap();
        assert_eq!(dh, dt);

        host.triad(f64::erase(&b), f64::erase(&a), f64::erase_mut(&mut dh), q)
            .unwrap();
        th.triad(f64::erase(&b), f64::erase(&a), f64::erase_mut(&mut dt), q)
            .unwrap();
        assert_eq!(dh, dt);
    }

    #[test]
    fn auto_threads_and_empty_vectors() {
        let th = ChunkedThreadedBackend::new(0);
        assert!(th.threads() >= 1);
        let th = ChunkedThreadedBackend::new(2);
        let mut d: [f64; 0] = [];
        th.copy(f64::erase(&[]), f64::erase_mut(&mut d)).unwrap();
        let a = [5i64];
        let mut id = [0i64];
        th.copy(i64::erase(&a), i64::erase_mut(&mut id)).unwrap();
        assert_eq!(id, [5]);
    }

    #[test]
    fn base_core_defaults_to_zero_without_worker_env() {
        // In-process case: no DISTARRAY_* env → leader window.
        assert_eq!(process_base_core(), 0);
    }

    /// The pool-parallel pack/unpack must be bit-identical to the
    /// serial engine path, and still one message per peer. A 64-byte
    /// tile forces the parallel path for any payload ≥ 8 f64.
    #[test]
    fn parallel_packed_remap_matches_serial_and_coalesces() {
        use crate::comm::{ChannelHub, Transport};
        use crate::darray::engine::execute_plan_typed;
        use crate::darray::RemapPlan;
        use crate::dmap::Dmap;
        use std::sync::Arc;

        let np = 3;
        let n = 120;
        let backend = Arc::new(ChunkedThreadedBackend::with_tile(3, 64));
        let world = ChannelHub::world(np);
        let mut hs = Vec::new();
        for t in world {
            let backend = backend.clone();
            hs.push(std::thread::spawn(move || {
                let pid = t.pid();
                let src_map = Dmap::block_1d(np);
                let dst_map = Dmap::cyclic_1d(np);
                let plan = RemapPlan::build(&src_map, &dst_map, &[n]);
                let src: Vec<f64> = (0..n)
                    .filter(|&g| src_map.owner(&[g], &[n]) == pid)
                    .map(|g| g as f64 * 0.5)
                    .collect();
                let mut via_backend = vec![0.0f64; dst_map.local_size(pid, &[n])];
                backend
                    .execute_plan(
                        &plan,
                        f64::erase(&src),
                        f64::erase_mut(&mut via_backend),
                        pid,
                        &t,
                        1,
                    )
                    .unwrap();
                // Serial reference on a second epoch over the same wire.
                let mut serial = vec![0.0f64; via_backend.len()];
                execute_plan_typed::<f64>(&plan, &src, &mut serial, pid, &t, 2).unwrap();
                assert_eq!(via_backend, serial, "pid {pid}");
                // One message per peer per epoch, both epochs.
                assert_eq!(
                    t.stats().msgs_sent() as usize,
                    2 * plan.peer_sends(pid).len(),
                    "pid {pid} message count"
                );
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
