//! [`ChunkedThreadedBackend`] — kernels tiled over cache-sized chunks
//! and fanned across an affinity-pinned worker pool.
//!
//! The §V thread axis as a backend: each kernel splits the vector into
//! one contiguous range per pool thread (contiguous, not interleaved,
//! to preserve streaming access — the same reason the paper pins
//! threads to adjacent cores), and each thread walks its range in
//! cache-sized tiles so a tile's working set stays resident between
//! the load and the store. The pool is a pinned
//! [`OpPool`](crate::stream::threaded::OpPool): spawned threads pin to
//! the adjacent cores of *this process's* launcher window
//! (`slot · Ntpn + tid`, from the `DISTARRAY_*` environment; base 0
//! for the leader and in-process runs), gracefully skipped when the
//! plan exceeds the machine.
//!
//! Element-wise determinism: tiling and threading change *which core*
//! computes an element, never the arithmetic, so results are
//! bit-identical to [`super::HostBackend`] — asserted by the
//! backend-equivalence property tests.

use super::{
    check_len, execute_plan_erased, expect_t, expect_t_mut, for_dtype, memcpy_erased, Backend,
    BackendKind, Result,
};
use crate::comm::Transport;
use crate::darray::RemapPlan;
use crate::dmap::Pid;
use crate::element::{Dtype, ElemSlice, ElemSliceMut, Element};
use crate::stream::ops;
use crate::stream::threaded::{chunk_bounds, OpPool};
use std::sync::OnceLock;

/// Default tile: 256 KiB — comfortably inside a per-core L2 while
/// large enough that loop overhead vanishes against memory traffic.
pub const DEFAULT_TILE_BYTES: usize = 256 * 1024;

/// First core of this process's launcher window: `slot × Ntpn` from
/// the `DISTARRAY_*` worker environment, 0 for the leader and for
/// in-process (test/SPMD) use. Keeps every process's pool inside its
/// own adjacent-core window instead of stacking all pools on core 0.
fn process_base_core() -> usize {
    let get = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<usize>().ok());
    match (get("DISTARRAY_SLOT"), get("DISTARRAY_NTPN")) {
        (Some(slot), Some(ntpn)) => slot * ntpn,
        _ => 0,
    }
}

/// Rebuild an immutable slice from an address smuggled across a
/// `'static` job closure as `usize`.
///
/// SAFETY (caller's obligations): `addr` must come from a live slice
/// of `T` with at least `i + len` elements that outlives the pool's
/// blocking `run` call, and `[i, i+len)` must be disjoint from every
/// range any thread mutates during that call.
unsafe fn slice_at<'a, T>(addr: usize, i: usize, len: usize) -> &'a [T] {
    std::slice::from_raw_parts((addr as *const T).add(i), len)
}

/// Mutable counterpart of [`slice_at`]; additionally requires that no
/// other thread touches `[i, i+len)` at all during the call.
unsafe fn slice_at_mut<'a, T>(addr: usize, i: usize, len: usize) -> &'a mut [T] {
    std::slice::from_raw_parts_mut((addr as *mut T).add(i), len)
}

/// Walk `[lo, hi)` in `tile`-element steps.
macro_rules! tiled {
    ($lo:expr, $hi:expr, $tile:expr, |$i:ident, $j:ident| $body:expr) => {{
        let mut $i = $lo;
        while $i < $hi {
            let $j = ($i + $tile).min($hi);
            $body;
            $i = $j;
        }
    }};
}

/// Affinity-pinned chunk-parallel backend.
pub struct ChunkedThreadedBackend {
    threads: usize,
    tile_bytes: usize,
    /// Lazily spawned: constructing the backend (e.g. in a registry)
    /// costs nothing until a kernel actually runs.
    pool: OnceLock<OpPool>,
}

impl ChunkedThreadedBackend {
    /// `threads == 0` means auto (one per online core).
    pub fn new(threads: usize) -> ChunkedThreadedBackend {
        ChunkedThreadedBackend::with_tile(threads, DEFAULT_TILE_BYTES)
    }

    /// Explicit cache-tile size in bytes (floored to one element).
    pub fn with_tile(threads: usize, tile_bytes: usize) -> ChunkedThreadedBackend {
        let threads = if threads == 0 {
            crate::launcher::pinning::online_cores()
        } else {
            threads
        };
        ChunkedThreadedBackend { threads, tile_bytes: tile_bytes.max(8), pool: OnceLock::new() }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn pool(&self) -> &OpPool {
        self.pool
            .get_or_init(|| OpPool::pinned(self.threads, process_base_core()))
    }

    fn tile_elems<T: Element>(&self) -> usize {
        (self.tile_bytes / T::WIDTH).max(1)
    }
}

impl Backend for ChunkedThreadedBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Threaded
    }

    fn prepare_alloc(&self, _dtype: Dtype, _len: usize) -> Result<()> {
        Ok(())
    }

    fn upload(&self, host: ElemSlice<'_>, dev: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(host, dev)
    }

    fn download(&self, dev: ElemSlice<'_>, host: ElemSliceMut<'_>) -> Result<()> {
        memcpy_erased(dev, host)
    }

    fn copy(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            let (sp, dp, n) = (s.as_ptr() as usize, d.as_mut_ptr() as usize, d.len());
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: per-tid chunks are disjoint subranges of
                    // slices that outlive this blocking `run` call.
                    let (sv, dv) = unsafe {
                        (slice_at::<T>(sp, i, j - i), slice_at_mut::<T>(dp, i, j - i))
                    };
                    ops::copy(dv, sv)
                });
            });
            Ok(())
        })
    }

    fn scale(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let s = expect_t::<T>(src)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), s.len())?;
            let q = T::from_f64(q);
            let (sp, dp, n) = (s.as_ptr() as usize, d.as_mut_ptr() as usize, d.len());
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (sv, dv) = unsafe {
                        (slice_at::<T>(sp, i, j - i), slice_at_mut::<T>(dp, i, j - i))
                    };
                    ops::scale(dv, sv, q)
                });
            });
            Ok(())
        })
    }

    fn add(&self, a: ElemSlice<'_>, b: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sa = expect_t::<T>(a)?;
            let sb = expect_t::<T>(b)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sa.len())?;
            check_len(d.len(), sb.len())?;
            let (ap, bp, dp, n) = (
                sa.as_ptr() as usize,
                sb.as_ptr() as usize,
                d.as_mut_ptr() as usize,
                d.len(),
            );
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (av, bv, dv) = unsafe {
                        (
                            slice_at::<T>(ap, i, j - i),
                            slice_at::<T>(bp, i, j - i),
                            slice_at_mut::<T>(dp, i, j - i),
                        )
                    };
                    ops::add(dv, av, bv)
                });
            });
            Ok(())
        })
    }

    fn triad(
        &self,
        b: ElemSlice<'_>,
        c: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        q: f64,
    ) -> Result<()> {
        for_dtype!(dst.dtype(), T, {
            let sb = expect_t::<T>(b)?;
            let sc = expect_t::<T>(c)?;
            let d = expect_t_mut::<T>(dst)?;
            check_len(d.len(), sb.len())?;
            check_len(d.len(), sc.len())?;
            let q = T::from_f64(q);
            let (bp, cp, dp, n) = (
                sb.as_ptr() as usize,
                sc.as_ptr() as usize,
                d.as_mut_ptr() as usize,
                d.len(),
            );
            let (threads, tile) = (self.threads, self.tile_elems::<T>());
            self.pool().run(move |tid| {
                let (lo, hi) = chunk_bounds(threads, n, tid);
                tiled!(lo, hi, tile, |i, j| {
                    // SAFETY: as in `copy`.
                    let (bv, cv, dv) = unsafe {
                        (
                            slice_at::<T>(bp, i, j - i),
                            slice_at::<T>(cp, i, j - i),
                            slice_at_mut::<T>(dp, i, j - i),
                        )
                    };
                    ops::triad(dv, bv, cv, q)
                });
            });
            Ok(())
        })
    }

    /// Plan execution is transport-bound, not compute-bound, so the
    /// transfer list runs serially on the caller — identical bytes and
    /// ordering to the host backend by construction.
    fn execute_plan(
        &self,
        plan: &RemapPlan,
        src: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        execute_plan_erased(plan, src, dst, pid, t, epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::super::HostBackend;
    use super::*;

    #[test]
    fn threaded_matches_host_bitwise() {
        let host = HostBackend::new();
        // A tiny tile so even a small vector crosses tile boundaries,
        // and more threads than divide n evenly.
        let th = ChunkedThreadedBackend::with_tile(3, 64);
        let n = 1013;
        let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 7.0).collect();
        let b: Vec<f64> = (0..n).map(|i| (i * i % 97) as f64).collect();
        let q = 0.414;

        let mut dh = vec![0.0f64; n];
        let mut dt = vec![0.0f64; n];
        host.scale(f64::erase(&a), f64::erase_mut(&mut dh), q).unwrap();
        th.scale(f64::erase(&a), f64::erase_mut(&mut dt), q).unwrap();
        assert_eq!(dh, dt);

        host.add(f64::erase(&a), f64::erase(&b), f64::erase_mut(&mut dh))
            .unwrap();
        th.add(f64::erase(&a), f64::erase(&b), f64::erase_mut(&mut dt))
            .unwrap();
        assert_eq!(dh, dt);

        host.triad(f64::erase(&b), f64::erase(&a), f64::erase_mut(&mut dh), q)
            .unwrap();
        th.triad(f64::erase(&b), f64::erase(&a), f64::erase_mut(&mut dt), q)
            .unwrap();
        assert_eq!(dh, dt);
    }

    #[test]
    fn auto_threads_and_empty_vectors() {
        let th = ChunkedThreadedBackend::new(0);
        assert!(th.threads() >= 1);
        let th = ChunkedThreadedBackend::new(2);
        let mut d: [f64; 0] = [];
        th.copy(f64::erase(&[]), f64::erase_mut(&mut d)).unwrap();
        let a = [5i64];
        let mut id = [0i64];
        th.copy(i64::erase(&a), i64::erase_mut(&mut id)).unwrap();
        assert_eq!(id, [5]);
    }

    #[test]
    fn base_core_defaults_to_zero_without_worker_env() {
        // In-process case: no DISTARRAY_* env → leader window.
        assert_eq!(process_base_core(), 0);
    }
}
