//! Plan-driven STREAM scheduling over any [`Backend`].
//!
//! The scheduler is the backend analogue of Algorithm 2: it maps one
//! PID's partition-local share of the global vectors onto device
//! buffers, drives the four kernels through the trait with the same
//! tic/toc discipline as the native engines, and validates against the
//! §III closed forms. The map algebra decides *what* is local; the
//! backend decides *how* it executes — user code (and the coordinator
//! protocol above it) stays identical across `--backend` values, which
//! is the paper's temporal-scaling claim made concrete.

use super::{Backend, BackendError, DeviceBuffer, Result};
use crate::dmap::{Dmap, Pid};
use crate::element::{Dtype, Element};
use crate::stream::serial::{A0, B0, C0};
use crate::stream::timing::{OpTimes, Timer};
use crate::stream::validate::{expected, tolerance_for, ValidationReport};
use crate::stream::{aggregate, AggregateResult, StreamResult};
use std::sync::Arc;

/// Max |x − e| over a downloaded vector — the same fold `validate_t`
/// runs, applied one vector at a time so a single staging buffer
/// serves all three downloads.
fn max_dev<T: Element>(xs: &[T], e: f64) -> f64 {
    xs.iter().map(|&x| (x.to_f64() - e).abs()).fold(0.0, f64::max)
}

/// Run one PID's STREAM share on `backend` at dtype `T` (SPMD: call on
/// every PID of `map` with the same arguments).
///
/// Memory: three device buffers plus ONE host staging vector (reused
/// for init uploads and the per-vector validation downloads) — 4·N
/// local elements total, vs the 3·N of the darray path; host-class
/// backends' buffers ARE host memory, so staging is the only overhead.
pub fn run_stream_t<T: Element>(
    backend: &dyn Backend,
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
    pid: Pid,
) -> Result<StreamResult> {
    assert!(nt >= 1);
    if !backend.available() {
        return Err(BackendError::Unavailable(backend.kind()));
    }
    let shape = [n_global];
    let n_local = map.local_size(pid, &shape);

    let mut da = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut db = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut dc = DeviceBuffer::<T>::alloc(backend, n_local)?;
    let mut stage = vec![T::from_f64(A0); n_local];
    da.upload_from(backend, &stage)?;
    stage.fill(T::from_f64(B0));
    db.upload_from(backend, &stage)?;
    stage.fill(T::from_f64(C0));
    dc.upload_from(backend, &stage)?;

    let qf = q.to_f64();
    let mut times = OpTimes::zero();
    for _ in 0..nt {
        let t = Timer::tic();
        backend.copy(da.view(), dc.view_mut())?; // C = A
        times.copy += t.toc();

        let t = Timer::tic();
        backend.scale(dc.view(), db.view_mut(), qf)?; // B = q·C
        times.scale += t.toc();

        let t = Timer::tic();
        backend.add(da.view(), db.view(), dc.view_mut())?; // C = A + B
        times.add += t.toc();

        let t = Timer::tic();
        backend.triad(db.view(), dc.view(), da.view_mut(), qf)?; // A = B + q·C
        times.triad += t.toc();
    }

    // §III closed-form validation, identical arithmetic to
    // `validate_t` but one downloaded vector at a time.
    let (ea, eb, ec) = expected(A0, qf, nt);
    da.download_into(backend, &mut stage)?;
    let err_a = max_dev(&stage, ea);
    db.download_into(backend, &mut stage)?;
    let err_b = max_dev(&stage, eb);
    dc.download_into(backend, &mut stage)?;
    let err_c = max_dev(&stage, ec);
    let tol = tolerance_for(T::TOL_BASE, nt);
    let validation = ValidationReport {
        passed: err_a <= tol && err_b <= tol && err_c <= tol,
        err_a,
        err_b,
        err_c,
    };
    Ok(StreamResult {
        n_global,
        n_local,
        nt,
        width: T::WIDTH,
        backend: backend.kind(),
        times,
        validation,
    })
}

/// Run every PID of `map` as one OS thread on a shared backend and
/// aggregate — the in-process SPMD driver of the backend path.
pub fn run_stream_spmd_t<T: Element>(
    backend: &Arc<dyn Backend>,
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: T,
) -> Result<AggregateResult> {
    let handles: Vec<_> = map
        .pids()
        .iter()
        .map(|&p| {
            let (b, m) = (backend.clone(), map.clone());
            std::thread::spawn(move || run_stream_t::<T>(b.as_ref(), &m, n_global, nt, q, p))
        })
        .collect();
    let mut results = Vec::with_capacity(handles.len());
    for h in handles {
        results.push(h.join().expect("scheduler thread panicked")?);
    }
    Ok(aggregate(&results).expect("map has at least one PID"))
}

/// Dispatch a runtime dtype token to [`run_stream_t`], narrowing the
/// scale factor exactly as the engine-level dispatch does.
pub fn run_stream_dtype(
    backend: &dyn Backend,
    map: &Dmap,
    n_global: usize,
    nt: usize,
    q: f64,
    dtype: Dtype,
    pid: Pid,
) -> Result<StreamResult> {
    match dtype {
        Dtype::F64 => run_stream_t::<f64>(backend, map, n_global, nt, q, pid),
        Dtype::F32 => run_stream_t::<f32>(backend, map, n_global, nt, q as f32, pid),
        Dtype::I64 => run_stream_t::<i64>(backend, map, n_global, nt, q as i64, pid),
        Dtype::U64 => run_stream_t::<u64>(backend, map, n_global, nt, q as u64, pid),
    }
}

/// A small bounded MPSC hand-off queue — the double-buffering
/// primitive of the compute-on-arrival datapath.
///
/// The receive loop (producer) pushes each landed
/// [`ArrivedChunk`](crate::comm::datapath::ArrivedChunk) while the
/// unpack thread (consumer) pops and scatters the previous one: chunk
/// `k` rides the wire while chunk `k − 1` is being consumed. The
/// bound keeps the producer from racing arbitrarily far ahead of a
/// slow consumer (bounded buffering, not unbounded queueing), and a
/// consumer-side [`ReadyQueue::close`] releases a blocked producer so
/// an unpack error can't deadlock the drain.
pub struct ReadyQueue<T> {
    state: std::sync::Mutex<RqState<T>>,
    /// Signaled when an item lands or the queue closes (consumer waits).
    avail: std::sync::Condvar,
    /// Signaled when capacity frees or the queue closes (producer waits).
    space: std::sync::Condvar,
    cap: usize,
}

struct RqState<T> {
    q: std::collections::VecDeque<T>,
    closed: bool,
}

impl<T> ReadyQueue<T> {
    /// A queue holding at most `cap` in-flight items (floored to 1).
    pub fn new(cap: usize) -> ReadyQueue<T> {
        ReadyQueue {
            state: std::sync::Mutex::new(RqState {
                q: std::collections::VecDeque::with_capacity(cap.max(1)),
                closed: false,
            }),
            avail: std::sync::Condvar::new(),
            space: std::sync::Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue one item, blocking while the queue is full. Returns
    /// `false` (dropping the item) if the queue was closed — the
    /// producer's signal to stop feeding.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.q.len() >= self.cap && !st.closed {
            st = self.space.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.q.push_back(item);
        drop(st);
        self.avail.notify_one();
        true
    }

    /// Dequeue the next item, blocking while the queue is empty.
    /// Returns `None` once the queue is closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.q.pop_front() {
                drop(st);
                self.space.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.avail.wait(st).unwrap();
        }
    }

    /// Close the queue: a draining consumer still sees every queued
    /// item; a blocked producer wakes and returns `false`. Called by
    /// the producer when its stream ends, or by the consumer on error.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.avail.notify_all();
        self.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BackendKind, BackendRegistry};
    use super::*;
    use crate::stream::STREAM_Q;

    #[test]
    fn ready_queue_is_fifo_across_threads() {
        let q = std::sync::Arc::new(ReadyQueue::<usize>::new(4));
        let qp = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                assert!(qp.push(i), "queue closed early");
            }
            qp.close();
        });
        let mut expect = 0usize;
        while let Some(i) = q.pop() {
            assert_eq!(i, expect, "FIFO order");
            expect += 1;
        }
        assert_eq!(expect, 1000, "every item delivered before close-drain");
        producer.join().unwrap();
    }

    #[test]
    fn ready_queue_close_releases_a_blocked_producer() {
        let q = std::sync::Arc::new(ReadyQueue::<usize>::new(1));
        assert!(q.push(0), "first push fits");
        let qp = q.clone();
        // Second push blocks on the full queue until the consumer
        // side closes (the unpack-error path).
        let producer = std::thread::spawn(move || qp.push(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(!producer.join().unwrap(), "close must reject the blocked push");
        // The queued item survives for a draining consumer.
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None, "closed and drained");
    }

    #[test]
    fn ready_queue_pop_blocks_until_item_or_close() {
        let q = std::sync::Arc::new(ReadyQueue::<&'static str>::new(2));
        let qc = q.clone();
        let consumer = std::thread::spawn(move || (qc.pop(), qc.pop()));
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(q.push("a"));
        q.close();
        assert_eq!(consumer.join().unwrap(), (Some("a"), None));
        assert!(!q.push("b"), "push after close is rejected");
    }

    #[test]
    fn host_backend_stream_validates() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        let be = reg.get(BackendKind::Host).unwrap();
        let r = run_stream_t::<f64>(be.as_ref(), &Dmap::block_1d(1), 10_000, 5, STREAM_Q, 0)
            .unwrap();
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.backend, BackendKind::Host);
        assert_eq!(r.n_local, 10_000);
    }

    #[test]
    fn threaded_backend_stream_validates_and_names_itself() {
        let reg = BackendRegistry::with_defaults(3, "artifacts");
        let be = reg.get(BackendKind::Threaded).unwrap();
        let r = run_stream_t::<f64>(be.as_ref(), &Dmap::block_1d(1), 40_001, 4, STREAM_Q, 0)
            .unwrap();
        assert!(r.validation.passed, "{:?}", r.validation);
        assert_eq!(r.backend, BackendKind::Threaded);
    }

    #[test]
    fn spmd_driver_covers_the_map() {
        let reg = BackendRegistry::with_defaults(2, "artifacts");
        let be = reg.get(BackendKind::Threaded).unwrap();
        let agg = run_stream_spmd_t::<f32>(
            be,
            &Dmap::block_1d(3),
            3 * 2048,
            3,
            std::f32::consts::SQRT_2 - 1.0,
        )
        .unwrap();
        assert!(agg.all_valid, "worst err {}", agg.worst_err);
        assert_eq!(agg.np, 3);
        assert_eq!(agg.width, 4);
        assert_eq!(agg.backend, BackendKind::Threaded);
    }

    #[test]
    fn dtype_dispatch_covers_all_tokens() {
        let reg = BackendRegistry::with_defaults(2, "artifacts");
        let be = reg.get(BackendKind::Host).unwrap();
        for dtype in [Dtype::F64, Dtype::F32, Dtype::I64, Dtype::U64] {
            let r = run_stream_dtype(
                be.as_ref(),
                &Dmap::block_1d(1),
                2048,
                3,
                STREAM_Q,
                dtype,
                0,
            )
            .unwrap();
            assert!(r.validation.passed, "{dtype}: {:?}", r.validation);
            assert_eq!(r.width, dtype.width());
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn unavailable_backend_errors_before_allocating() {
        let reg = BackendRegistry::with_defaults(1, "artifacts");
        let be = reg.get(BackendKind::Pjrt).unwrap();
        let err = run_stream_t::<f64>(be.as_ref(), &Dmap::block_1d(1), 64, 1, STREAM_Q, 0);
        assert!(matches!(err, Err(BackendError::Unavailable(BackendKind::Pjrt))));
    }
}
