//! [`PjrtBackend`] — STREAM kernels routed through the AOT PJRT
//! artifacts ([`crate::runtime::PjrtRuntime`]).
//!
//! The runtime is the feature gate: default (offline) builds ship the
//! runtime stub whose `load` reports `Unavailable`, so this backend
//! constructs everywhere, answers [`Backend::available`] honestly, and
//! every kernel returns [`BackendError::Unavailable`] without any
//! `cfg` in this file. Builds with `--features pjrt` (vendored `xla`)
//! plus generated artifacts get real artifact execution.
//!
//! Device model: [`DeviceBuffer`](super::DeviceBuffer) storage is the
//! host staging mirror; each kernel stages its operands through the
//! compiled artifact (one device round-trip per op), exactly like the
//! engine-level `EngineKind::Pjrt` path. The artifacts are lowered at
//! a fixed vector length `rt.n()` and in f64, so kernels accept f64
//! views whose length is a whole multiple of `rt.n()` and report
//! everything else as [`BackendError::Unsupported`].

use super::{
    check_len, execute_plan_erased, expect_t, expect_t_mut, memcpy_erased, Backend, BackendError,
    BackendKind, Result,
};
use crate::comm::Transport;
use crate::darray::RemapPlan;
use crate::dmap::Pid;
use crate::element::{Dtype, ElemSlice, ElemSliceMut};
use crate::runtime::PjrtRuntime;
use std::sync::OnceLock;

/// The PJRT artifact backend (f64, fixed artifact length).
pub struct PjrtBackend {
    artifacts_dir: String,
    /// Loaded (and compiled) on first use — a registry can construct
    /// this backend for a `--backend host` run without paying artifact
    /// I/O and compilation.
    rt: OnceLock<Option<PjrtRuntime>>,
}

impl PjrtBackend {
    /// Backend over the artifacts in `artifacts_dir`; loading is
    /// deferred to first use. An unavailable runtime (default build,
    /// or missing artifacts) yields a constructed-but-unavailable
    /// backend.
    pub fn new(artifacts_dir: &str) -> PjrtBackend {
        PjrtBackend { artifacts_dir: artifacts_dir.to_string(), rt: OnceLock::new() }
    }

    fn runtime(&self) -> Option<&PjrtRuntime> {
        self.rt
            .get_or_init(|| {
                PjrtRuntime::load_subset(&self.artifacts_dir, &["copy", "scale", "add", "triad"])
                    .ok()
            })
            .as_ref()
    }

    fn rt(&self) -> Result<&PjrtRuntime> {
        self.runtime()
            .ok_or(BackendError::Unavailable(BackendKind::Pjrt))
    }

    /// The artifacts are lowered for fixed-length f64 vectors; check
    /// both and return the chunk length.
    fn check_f64_len(&self, dtype: Dtype, len: usize) -> Result<usize> {
        let rt = self.rt()?;
        if dtype != Dtype::F64 {
            return Err(BackendError::Unsupported {
                backend: BackendKind::Pjrt,
                what: format!("dtype {dtype} (artifacts are lowered in f64)"),
            });
        }
        let chunk = rt.n();
        if chunk == 0 || len % chunk != 0 {
            return Err(BackendError::Unsupported {
                backend: BackendKind::Pjrt,
                what: format!("length {len} (must be a multiple of artifact n={chunk})"),
            });
        }
        Ok(chunk)
    }
}

fn rt_err(e: crate::runtime::RuntimeError) -> BackendError {
    BackendError::Runtime(e.to_string())
}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn available(&self) -> bool {
        self.runtime().is_some()
    }

    fn prepare_alloc(&self, dtype: Dtype, len: usize) -> Result<()> {
        self.check_f64_len(dtype, len).map(|_| ())
    }

    fn upload(&self, host: ElemSlice<'_>, dev: ElemSliceMut<'_>) -> Result<()> {
        self.rt()?;
        // Staging-mirror model: the host-visible copy IS the staging
        // buffer; the device hop happens inside each kernel.
        memcpy_erased(host, dev)
    }

    fn download(&self, dev: ElemSlice<'_>, host: ElemSliceMut<'_>) -> Result<()> {
        self.rt()?;
        memcpy_erased(dev, host)
    }

    fn copy(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        let chunk = self.check_f64_len(dst.dtype(), dst.len())?;
        let s = expect_t::<f64>(src)?;
        let d = expect_t_mut::<f64>(dst)?;
        check_len(d.len(), s.len())?;
        let rt = self.rt()?;
        for k in (0..d.len()).step_by(chunk) {
            let out = rt.copy(&s[k..k + chunk]).map_err(rt_err)?;
            d[k..k + chunk].copy_from_slice(&out);
        }
        Ok(())
    }

    fn scale(&self, src: ElemSlice<'_>, dst: ElemSliceMut<'_>, q: f64) -> Result<()> {
        let chunk = self.check_f64_len(dst.dtype(), dst.len())?;
        let s = expect_t::<f64>(src)?;
        let d = expect_t_mut::<f64>(dst)?;
        check_len(d.len(), s.len())?;
        let rt = self.rt()?;
        for k in (0..d.len()).step_by(chunk) {
            let out = rt.scale(&s[k..k + chunk], q).map_err(rt_err)?;
            d[k..k + chunk].copy_from_slice(&out);
        }
        Ok(())
    }

    fn add(&self, a: ElemSlice<'_>, b: ElemSlice<'_>, dst: ElemSliceMut<'_>) -> Result<()> {
        let chunk = self.check_f64_len(dst.dtype(), dst.len())?;
        let sa = expect_t::<f64>(a)?;
        let sb = expect_t::<f64>(b)?;
        let d = expect_t_mut::<f64>(dst)?;
        check_len(d.len(), sa.len())?;
        check_len(d.len(), sb.len())?;
        let rt = self.rt()?;
        for k in (0..d.len()).step_by(chunk) {
            let out = rt.add(&sa[k..k + chunk], &sb[k..k + chunk]).map_err(rt_err)?;
            d[k..k + chunk].copy_from_slice(&out);
        }
        Ok(())
    }

    fn triad(
        &self,
        b: ElemSlice<'_>,
        c: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        q: f64,
    ) -> Result<()> {
        let chunk = self.check_f64_len(dst.dtype(), dst.len())?;
        let sb = expect_t::<f64>(b)?;
        let sc = expect_t::<f64>(c)?;
        let d = expect_t_mut::<f64>(dst)?;
        check_len(d.len(), sb.len())?;
        check_len(d.len(), sc.len())?;
        let rt = self.rt()?;
        for k in (0..d.len()).step_by(chunk) {
            let out = rt
                .triad(&sb[k..k + chunk], &sc[k..k + chunk], q)
                .map_err(rt_err)?;
            d[k..k + chunk].copy_from_slice(&out);
        }
        Ok(())
    }

    /// Remap payloads move through the host staging mirror (the
    /// paper's file-based messaging stages through shared storage the
    /// same way), so plan execution is dtype-independent here even
    /// though the kernels are f64-only.
    fn execute_plan(
        &self,
        plan: &RemapPlan,
        src: ElemSlice<'_>,
        dst: ElemSliceMut<'_>,
        pid: Pid,
        t: &dyn Transport,
        epoch: u64,
    ) -> Result<()> {
        self.rt()?;
        execute_plan_erased(plan, src, dst, pid, t, epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::element::Element;

    // These tests pin the *default-build* contract: constructed, not
    // available, every operation a clean `Unavailable` (never a
    // panic). The `pjrt`-feature build exercises the real path via
    // `repro validate` and the integration tests.
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_build_is_cleanly_unavailable() {
        let be = PjrtBackend::new("artifacts");
        assert!(!be.available());
        assert_eq!(be.kind(), BackendKind::Pjrt);
        assert!(matches!(
            be.prepare_alloc(Dtype::F64, 8),
            Err(BackendError::Unavailable(BackendKind::Pjrt))
        ));
        let a = [1.0f64; 4];
        let mut d = [0.0f64; 4];
        assert!(matches!(
            be.copy(f64::erase(&a), f64::erase_mut(&mut d)),
            Err(BackendError::Unavailable(BackendKind::Pjrt))
        ));
        assert!(matches!(
            be.upload(f64::erase(&a), f64::erase_mut(&mut d)),
            Err(BackendError::Unavailable(BackendKind::Pjrt))
        ));
    }
}
