//! Ring collectives — chunked pipelining for bandwidth, plus the
//! dissemination barrier.
//!
//! * broadcast — the payload is cut into chunks that flow down the
//!   chain `0 → 1 → … → P−1`; rank `i` forwards chunk `c` the moment
//!   it lands, so all links stream concurrently once the pipe fills.
//!   `(P−1) × chunks` messages; per-rank bandwidth approaches the
//!   link bandwidth independent of P (the star saturates the root's
//!   link at 1/(P−1) of that). Chunk 0 carries a
//!   `[total][n_chunks]` header so downstream ranks can size buffers
//!   without a separate round.
//! * gather — a chain toward the root: rank `P−1` starts a framed
//!   bundle, each rank appends its part and forwards. P−1 messages
//!   but the accumulated bundle is re-serialized at every hop —
//!   O(P²·part) total wire bytes with O(P) serial depth, so this is
//!   a control-plane gather (scalar reductions, worker reports), not
//!   a bulk one; large aggregations should prefer `tree`/`hier`
//!   (reduce-scatter pipelining is a ROADMAP item).
//! * barrier — the dissemination algorithm: in round `r` every rank
//!   signals `(me + 2^r) mod P` and waits on `(me − 2^r) mod P`;
//!   after `ceil(log2 P)` rounds every rank transitively covers every
//!   other. No root, `P·ceil(log2 P)` messages, log depth.

use super::{bundle, log2_rounds, TagSpace, PH_BCAST, PH_DISSEM, PH_GATHER};
use crate::comm::{CommError, Result, Transport, WireReader, WireWriter};
use crate::dmap::Pid;
use std::time::Duration;

/// Hard cap on pipeline chunks (the tag round field is 16 bits).
const MAX_CHUNKS: usize = 1 << 16;

/// The chunk size actually used for an `n`-byte payload: the
/// configured size, raised if needed so the chunk count fits the tag
/// field.
fn chunk_for(n: usize, chunk_bytes: usize) -> usize {
    chunk_bytes.max(1).max(n.div_ceil(MAX_CHUNKS))
}

/// Chunked pipelined broadcast from `group[0]` down the chain.
pub(crate) fn bcast(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    chunk_bytes: usize,
    payload: Vec<u8>,
) -> Result<Vec<u8>> {
    let p = group.len();
    if p == 1 {
        return Ok(payload);
    }
    if me == 0 {
        let n = payload.len();
        let cb = chunk_for(n, chunk_bytes);
        let nchunks = n.div_ceil(cb).max(1);
        for c in 0..nchunks {
            let lo = c * cb;
            let hi = (lo + cb).min(n);
            let tag = space.at(level, PH_BCAST, c as u64);
            if c == 0 {
                let mut w = WireWriter::with_capacity(16 + (hi - lo));
                w.put_u64(n as u64);
                w.put_u64(nchunks as u64);
                let mut msg = w.finish();
                msg.extend_from_slice(&payload[lo..hi]);
                t.send(group[1], tag, &msg)?;
            } else {
                t.send(group[1], tag, &payload[lo..hi])?;
            }
        }
        Ok(payload)
    } else {
        let prev = group[me - 1];
        let next = if me + 1 < p { Some(group[me + 1]) } else { None };
        let first = t.recv(prev, space.at(level, PH_BCAST, 0))?;
        if let Some(nx) = next {
            t.send(nx, space.at(level, PH_BCAST, 0), &first)?;
        }
        let mut rd = WireReader::new(&first);
        let total = rd.get_usize()?;
        let nchunks = rd.get_usize()?;
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(rd.take_raw(rd.remaining())?);
        for c in 1..nchunks {
            let tag = space.at(level, PH_BCAST, c as u64);
            let chunk = t.recv(prev, tag)?;
            if let Some(nx) = next {
                t.send(nx, tag, &chunk)?;
            }
            out.extend_from_slice(&chunk);
        }
        if out.len() != total {
            return Err(CommError::Malformed(format!(
                "ring bcast reassembled {} of {total} bytes",
                out.len()
            )));
        }
        Ok(out)
    }
}

/// Chain gather toward `group[0]`: returns `Some(parts)` in rank
/// order at the root, `None` elsewhere.
pub(crate) fn gather(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    part: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    let p = group.len();
    let mut acc: Vec<(u64, Vec<u8>)> = Vec::with_capacity(p - me);
    if me + 1 < p {
        let payload = t.recv(group[me + 1], space.at(level, PH_GATHER, (me + 1) as u64))?;
        bundle::read(&payload, &mut acc)?;
    }
    acc.push((me as u64, part));
    if me > 0 {
        t.send(group[me - 1], space.at(level, PH_GATHER, me as u64), &bundle::write(&acc))?;
        Ok(None)
    } else {
        bundle::into_rank_order(acc, p).map(Some)
    }
}

/// Dissemination barrier (no root; every rank sends and receives one
/// empty message per round).
pub(crate) fn barrier(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    timeout: Duration,
) -> Result<()> {
    let p = group.len();
    for r in 0..log2_rounds(p) {
        let d = 1usize << r;
        let tag = space.at(level, PH_DISSEM, r as u64);
        t.send(group[(me + d) % p], tag, &[])?;
        t.recv_timeout(group[(me + p - d) % p], tag, timeout)?;
    }
    Ok(())
}
