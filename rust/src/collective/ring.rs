//! Ring collectives — chunked pipelining for bandwidth, plus the
//! dissemination barrier. Every data-bearing path rides the shared
//! [`ChunkStream`] datapath (pooled frame buffers, the 16-bit chunk
//! cap enforced once, arrival-order drains).
//!
//! * broadcast — the payload is cut into chunks that flow down the
//!   chain `0 → 1 → … → P−1`; rank `i` forwards chunk `c` the moment
//!   it lands ([`ChunkStream::recv_forward`]), so all links stream
//!   concurrently once the pipe fills. `(P−1) × chunks` messages;
//!   per-rank bandwidth approaches the link bandwidth independent of
//!   P (the star saturates the root's link at 1/(P−1) of that).
//!   Chunk 0 carries the stream's `[total][n_chunks]` frame so
//!   downstream ranks can size buffers without a separate round.
//! * gather — chunk-pipelined and **direct**: every rank streams its
//!   part straight to the root, which drains all senders in arrival
//!   order ([`ChunkStream::drain`]). `(P−1) × chunks` messages and
//!   O(P·part) total wire bytes — this replaces the old accumulating
//!   chain, which re-serialized its bundle at every hop for
//!   O(P²·part) wire bytes and O(P) serial depth, making ring gathers
//!   safe for bulk payloads, not just control-plane sizes.
//! * barrier — the dissemination algorithm: in round `r` every rank
//!   signals `(me + 2^r) mod P` and waits on `(me − 2^r) mod P`;
//!   after `ceil(log2 P)` rounds every rank transitively covers every
//!   other. No root, `P·ceil(log2 P)` messages, log depth.

use super::{log2_rounds, TagSpace, PH_BCAST, PH_DISSEM, PH_GATHER};
use crate::comm::datapath::ChunkStream;
use crate::comm::{Result, Transport};
use crate::dmap::Pid;
use std::time::Duration;

/// Chunked pipelined broadcast from `group[0]` down the chain.
pub(crate) fn bcast(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    chunk_bytes: usize,
    payload: Vec<u8>,
) -> Result<Vec<u8>> {
    let p = group.len();
    if p == 1 {
        return Ok(payload);
    }
    let tag = space.chunk_tag(level, PH_BCAST);
    if me == 0 {
        ChunkStream::send(t, group[1], tag, chunk_bytes, &[&payload])?;
        Ok(payload)
    } else {
        let next = if me + 1 < p { Some(group[me + 1]) } else { None };
        ChunkStream::recv_forward(t, group[me - 1], tag, next)
    }
}

/// Chunk-pipelined direct gather toward `group[0]`: returns
/// `Some(parts)` in rank order at the root, `None` elsewhere.
pub(crate) fn gather(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    chunk_bytes: usize,
    part: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    let p = group.len();
    let tag = space.chunk_tag(level, PH_GATHER);
    if me > 0 {
        ChunkStream::send(t, group[0], tag, chunk_bytes, &[&part])?;
        return Ok(None);
    }
    let mut parts: Vec<Vec<u8>> = vec![Vec::new(); p];
    parts[0] = part;
    let peers = &group[1..];
    ChunkStream::drain(t, peers, tag, |i, payload| {
        parts[i + 1] = payload;
        Ok(())
    })?;
    Ok(Some(parts))
}

/// Dissemination barrier (no root; every rank sends and receives one
/// empty message per round).
pub(crate) fn barrier(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    timeout: Duration,
) -> Result<()> {
    let p = group.len();
    for r in 0..log2_rounds(p) {
        let d = 1usize << r;
        let tag = space.at(level, PH_DISSEM, r as u64);
        t.send(group[(me + d) % p], tag, &[])?;
        t.recv_timeout(group[(me + p - d) % p], tag, timeout)?;
    }
    Ok(())
}
