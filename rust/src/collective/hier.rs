//! Hierarchical (two-level) collectives — the topology-aware
//! composition that makes horizontal scaling linear.
//!
//! Every operation decomposes along the [`Topology`](super::Topology)
//! node boundary:
//!
//! * intra-node phases run the **star** algorithm inside each node
//!   group (node-local hops are cheap — shared memory or one switch);
//! * the inter-node phase runs the **binomial tree** across one
//!   *leader* per node (the node group's first PID), so the expensive
//!   cross-node links carry O(log Nnode) depth and Nnode−1 messages
//!   instead of O(Np) at a single rank.
//!
//! Gather therefore costs `(P − L)` intra-node messages plus `L − 1`
//! inter-node messages (L = node count) — total P−1, same as the flat
//! tree, but with the cross-node share shrunk from P−1 to L−1.
//! Tag levels keep the three phases (intra-pre = 0, inter = 1,
//! intra-post = 2) in disjoint tag streams.

use super::{bundle, star, tree, TagSpace, PH_BCAST, PH_DOWN, PH_GATHER, PH_UP};
use super::Topology;
use crate::comm::datapath;
use crate::comm::{Result, Transport};
use crate::dmap::Pid;
use std::time::Duration;

/// Tag level of the intra-node phase that precedes the inter phase.
const LV_INTRA_PRE: u64 = 0;
/// Tag level of the inter-node (leaders-only) phase.
const LV_INTER: u64 = 1;
/// Tag level of the intra-node phase that follows the inter phase.
const LV_INTRA_POST: u64 = 2;

/// One PID's view of the two-level decomposition of `group`.
struct View {
    /// Per-node participant lists (root's node first, root leading).
    nodes: Vec<Vec<Pid>>,
    /// One leader (first member) per node, in node order.
    leaders: Vec<Pid>,
    /// Index of my node in `nodes`.
    my_node: usize,
    /// My index within my node's list (0 ⇔ I lead it).
    my_slot: usize,
}

impl View {
    fn build(topo: &Topology, group: &[Pid], me_pid: Pid) -> Result<View> {
        let nodes = topo.restrict(group)?;
        let leaders: Vec<Pid> = nodes.iter().map(|g| g[0]).collect();
        let (my_node, my_slot) = nodes
            .iter()
            .enumerate()
            .find_map(|(k, g)| g.iter().position(|&p| p == me_pid).map(|s| (k, s)))
            .expect("caller verified membership");
        Ok(View { nodes, leaders, my_node, my_slot })
    }

    fn is_leader(&self) -> bool {
        self.my_slot == 0
    }

    fn my_group(&self) -> &[Pid] {
        &self.nodes[self.my_node]
    }
}

/// Two-level broadcast: tree across leaders, star fan-out inside each
/// node.
pub(crate) fn bcast(
    t: &dyn Transport,
    topo: &Topology,
    group: &[Pid],
    me_pid: Pid,
    space: &TagSpace,
    payload: Vec<u8>,
) -> Result<Vec<u8>> {
    let v = View::build(topo, group, me_pid)?;
    let data = if v.is_leader() {
        tree::bcast(t, &v.leaders, v.my_node, space, LV_INTER, payload)?
    } else {
        payload
    };
    // Intra-node fan-out. Disjoint node memberships keep the shared
    // (level, phase, round) tag unambiguous: `(from, tag)` differs per
    // node.
    star::bcast(
        t,
        v.my_group(),
        v.my_slot,
        space.at(LV_INTRA_POST, PH_BCAST, 0),
        data,
    )
}

/// Two-level gather to `group[0]`: star into each node leader, then
/// tree of per-node bundles across leaders. Returns parts in
/// group-rank order at the root.
pub(crate) fn gather(
    t: &dyn Transport,
    topo: &Topology,
    group: &[Pid],
    me_pid: Pid,
    space: &TagSpace,
    part: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    let v = View::build(topo, group, me_pid)?;
    let node_parts = star::gather(
        t,
        v.my_group(),
        v.my_slot,
        space.at(LV_INTRA_PRE, PH_GATHER, 0),
        part,
    )?;
    let Some(node_parts) = node_parts else {
        return Ok(None); // non-leader: done after the intra hop
    };
    // Leader: re-key the node's parts by *group* rank and bundle them
    // for the inter phase (one O(|group|) index build, not a scan per
    // member).
    let rank_of: std::collections::HashMap<Pid, u64> = group
        .iter()
        .enumerate()
        .map(|(i, &p)| (p, i as u64))
        .collect();
    let ranks: Vec<u64> = v.my_group().iter().map(|p| rank_of[p]).collect();
    let entries: Vec<(u64, Vec<u8>)> = ranks.into_iter().zip(node_parts).collect();
    let node_bundle = bundle::write(&entries);
    let Some(leader_bundles) = tree::gather(
        t,
        &v.leaders,
        v.my_node,
        space,
        LV_INTER,
        datapath::ambient_chunk_bytes(),
        node_bundle,
    )?
    else {
        return Ok(None); // non-root leader
    };
    // Root: splice every node bundle into one dense rank-ordered list.
    let mut acc: Vec<(u64, Vec<u8>)> = Vec::with_capacity(group.len());
    for b in &leader_bundles {
        bundle::read(b, &mut acc)?;
    }
    bundle::into_rank_order(acc, group.len()).map(Some)
}

/// Two-level barrier: members report to their leader, leaders run a
/// tree barrier, leaders release their members.
pub(crate) fn barrier(
    t: &dyn Transport,
    topo: &Topology,
    group: &[Pid],
    me_pid: Pid,
    space: &TagSpace,
    timeout: Duration,
) -> Result<()> {
    let v = View::build(topo, group, me_pid)?;
    let up = space.at(LV_INTRA_PRE, PH_UP, 0);
    let down = space.at(LV_INTRA_POST, PH_DOWN, 0);
    if v.is_leader() {
        for &m in &v.my_group()[1..] {
            t.recv_timeout(m, up, timeout)?;
        }
        tree::barrier(t, &v.leaders, v.my_node, space, LV_INTER, timeout)?;
        for &m in &v.my_group()[1..] {
            t.send(m, down, &[])?;
        }
    } else {
        let leader = v.my_group()[0];
        t.send(leader, up, &[])?;
        t.recv_timeout(leader, down, timeout)?;
    }
    Ok(())
}
