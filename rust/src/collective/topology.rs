//! [`Topology`] — the (node, slot) structure of a launch, the seam
//! the hierarchical collectives exploit.
//!
//! The launcher's `[Nnode Nppn Ntpn]` triples (§V) already say which
//! PIDs share a node: processes are dealt node-major, so node `k`
//! hosts PIDs `k·Nppn .. (k+1)·Nppn`. A [`Topology`] materializes
//! that grouping as explicit per-node PID lists, and the
//! [`hier`](super) composition runs its intra-node phases inside one
//! group and its inter-node phase across one representative (the
//! *leader*, the group's first PID) per group — O(Nppn) cheap local
//! hops plus O(log Nnode) expensive cross-node hops, instead of
//! O(Np) cross-node hops at one rank.

use crate::dmap::Pid;
use crate::launcher::Triples;

/// Node-grouped PID lists. Groups are non-empty and disjoint; PIDs
/// not covered by any group are treated as singleton nodes by
/// [`Topology::restrict`]. A pid → node index built at construction
/// keeps [`Topology::node_of`] (and therefore the per-call setup of
/// every hierarchical collective) O(1) per PID.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    nodes: Vec<Vec<Pid>>,
    node_ix: std::collections::HashMap<Pid, usize>,
}

impl Topology {
    fn from_nodes(nodes: Vec<Vec<Pid>>) -> Topology {
        let mut node_ix = std::collections::HashMap::new();
        for (k, g) in nodes.iter().enumerate() {
            for &p in g {
                node_ix.insert(p, k);
            }
        }
        Topology { nodes, node_ix }
    }

    /// Everything on one node — the degenerate topology under which
    /// `hier` collapses to its intra-node algorithm.
    pub fn flat(np: usize) -> Topology {
        Topology::from_nodes(vec![(0..np).collect()])
    }

    /// Consecutive groups of `per_node` PIDs (the launcher's
    /// node-major deal); the last group takes the remainder.
    /// `per_node == 0` means "unknown" and yields [`Topology::flat`].
    pub fn grouped(np: usize, per_node: usize) -> Topology {
        if per_node == 0 || per_node >= np {
            return Topology::flat(np);
        }
        let nodes = (0..np.div_ceil(per_node))
            .map(|k| (k * per_node..((k + 1) * per_node).min(np)).collect())
            .collect();
        Topology::from_nodes(nodes)
    }

    /// The topology of a triples-mode launch (`Nnode` groups of
    /// `Nppn` consecutive PIDs).
    pub fn from_triples(t: &Triples) -> Topology {
        Topology::grouped(t.np(), t.nppn)
    }

    /// Explicit groups (must be non-empty and pairwise disjoint).
    pub fn from_groups(groups: Vec<Vec<Pid>>) -> Topology {
        assert!(!groups.is_empty(), "topology needs at least one node");
        let mut seen = std::collections::HashSet::new();
        for g in &groups {
            assert!(!g.is_empty(), "empty node group");
            for &p in g {
                assert!(seen.insert(p), "pid {p} appears in two node groups");
            }
        }
        Topology::from_nodes(groups)
    }

    /// Total PIDs covered.
    pub fn np(&self) -> usize {
        self.nodes.iter().map(Vec::len).sum()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[Vec<Pid>] {
        &self.nodes
    }

    /// Index of the node group containing `pid` (O(1)).
    pub fn node_of(&self, pid: Pid) -> Option<usize> {
        self.node_ix.get(&pid).copied()
    }

    /// Intersect the topology with an ordered participant `group`:
    /// per-node sub-lists keeping `group`'s member order, empty nodes
    /// dropped, and any participant outside the topology promoted to
    /// a singleton node (so a mismatched topology degrades to extra
    /// inter-node traffic, never a hang). The node containing
    /// `group[0]` (the operation root) is rotated to the front and
    /// the root to the front of its node, preserving the invariant
    /// that the first PID of the first node is the global root.
    ///
    /// An empty `group` is an error, not a panic: after a failure
    /// every group is a survivor set and may legitimately come up
    /// empty, and a failure-path API must not abort the leader.
    pub fn restrict(&self, group: &[Pid]) -> crate::comm::Result<Vec<Vec<Pid>>> {
        if group.is_empty() {
            return Err(crate::comm::CommError::Malformed(
                "topology restrict of an empty group (no survivors?)".into(),
            ));
        }
        let mut out: Vec<Vec<Pid>> = Vec::new();
        let mut node_slot: Vec<Option<usize>> = vec![None; self.nodes.len()];
        for &p in group {
            match self.node_of(p) {
                Some(k) => match node_slot[k] {
                    Some(i) => out[i].push(p),
                    None => {
                        node_slot[k] = Some(out.len());
                        out.push(vec![p]);
                    }
                },
                None => out.push(vec![p]),
            }
        }
        // Rotate the root's node first, and the root to its head.
        let root = group[0];
        let rn = out
            .iter()
            .position(|g| g.contains(&root))
            .expect("root is a group member");
        out.swap(0, rn);
        let rs = out[0].iter().position(|&p| p == root).unwrap();
        out[0].swap(0, rs);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_splits_node_major() {
        let t = Topology::grouped(8, 3);
        assert_eq!(t.nodes(), &[vec![0, 1, 2], vec![3, 4, 5], vec![6, 7]]);
        assert_eq!(t.np(), 8);
        assert_eq!(t.node_count(), 3);
        assert_eq!(t.node_of(4), Some(1));
        assert_eq!(t.node_of(9), None);
    }

    #[test]
    fn zero_or_oversized_per_node_is_flat() {
        assert_eq!(Topology::grouped(4, 0), Topology::flat(4));
        assert_eq!(Topology::grouped(4, 8), Topology::flat(4));
        assert_eq!(Topology::flat(4).node_count(), 1);
    }

    #[test]
    fn from_triples_matches_node_major_deal() {
        let t = Topology::from_triples(&Triples::new(2, 4, 1));
        assert_eq!(t.nodes(), &[vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
    }

    #[test]
    fn restrict_keeps_order_and_roots_first() {
        let t = Topology::grouped(8, 2); // {0,1}{2,3}{4,5}{6,7}
        let g = t.restrict(&[0, 1, 2, 3, 6]).unwrap();
        assert_eq!(g, vec![vec![0, 1], vec![2, 3], vec![6]]);
        // A root in a later node rotates to the front.
        let g = t.restrict(&[5, 0, 1, 4]).unwrap();
        assert_eq!(g[0], vec![5, 4]);
        assert_eq!(g[1], vec![0, 1]);
    }

    #[test]
    fn restrict_promotes_unknown_pids_to_singletons() {
        let t = Topology::grouped(4, 2);
        let g = t.restrict(&[0, 1, 9]).unwrap();
        assert_eq!(g, vec![vec![0, 1], vec![9]]);
    }

    #[test]
    fn restrict_of_empty_group_is_an_error_not_a_panic() {
        let t = Topology::grouped(4, 2);
        let err = t.restrict(&[]).unwrap_err();
        assert!(err.to_string().contains("empty group"), "{err}");
    }

    #[test]
    #[should_panic(expected = "two node groups")]
    fn overlapping_groups_panic() {
        Topology::from_groups(vec![vec![0, 1], vec![1, 2]]);
    }
}
