//! Star (leader-centric) collectives — the reference algorithm.
//!
//! Exactly the shape the repo shipped before the collective subsystem
//! existed: every non-root exchanges directly with the root, one
//! message at a time, under a **single** tag. O(P) messages and O(P)
//! serialized latency at the root — correct at any scale, fast only
//! at small P. `--coll star` routes every call site through these
//! functions with the call site's legacy tag, so the wire behavior
//! (peers, order, payload bytes, tags) is bit-for-bit the
//! pre-subsystem behavior.

use crate::comm::{Result, Tag, Transport};
use crate::dmap::Pid;
use std::time::Duration;

/// Root (`group[0]`) sends `payload` to every other member in group
/// order; everyone returns the payload.
pub(crate) fn bcast(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    tag: Tag,
    payload: Vec<u8>,
) -> Result<Vec<u8>> {
    if me == 0 {
        for &to in &group[1..] {
            t.send(to, tag, &payload)?;
        }
        Ok(payload)
    } else {
        t.recv(group[0], tag)
    }
}

/// Every non-root sends its raw `part` to the root; the root returns
/// all parts in group-rank order (receiving in group order — the
/// legacy `agg`/result-gather loop).
pub(crate) fn gather(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    tag: Tag,
    part: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    if me == 0 {
        let mut parts = Vec::with_capacity(group.len());
        parts.push(part);
        for &from in &group[1..] {
            parts.push(t.recv(from, tag)?);
        }
        Ok(Some(parts))
    } else {
        t.send(group[0], tag, &part)?;
        Ok(None)
    }
}

/// Two-phase star barrier: all report to the root, the root releases
/// everyone (the legacy `comm::barrier` shape).
pub(crate) fn barrier(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    tag: Tag,
    timeout: Duration,
) -> Result<()> {
    if me == 0 {
        for &from in &group[1..] {
            t.recv_timeout(from, tag, timeout)?;
        }
        for &to in &group[1..] {
            t.send(to, tag, &[])?;
        }
    } else {
        t.send(group[0], tag, &[])?;
        t.recv_timeout(group[0], tag, timeout)?;
    }
    Ok(())
}
