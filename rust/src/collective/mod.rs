//! Topology-aware collective communication.
//!
//! The paper's headline claim is *linear horizontal scaling* —
//! hundreds of nodes sustaining >1 PB/s — and the repo's original
//! collectives were exactly the thing that breaks it: every reduce,
//! gather, broadcast, and barrier funneled through PID 0, one message
//! at a time (O(P) serialized hops at one rank). This subsystem makes
//! the *algorithm* a pluggable axis, the same way [`crate::backend`]
//! made execution pluggable:
//!
//! | kind   | broadcast            | gather/reduce        | barrier              |
//! |--------|----------------------|----------------------|----------------------|
//! | `star` | root → each (legacy) | each → root (legacy) | report/release       |
//! | `tree` | binomial, log depth  | binomial, P−1 msgs   | binomial up/down     |
//! | `ring` | chunked pipeline     | direct, chunked      | dissemination        |
//! | `hier` | star-in-node + tree-across-leaders (two-level)              |||
//! | `auto` | picks per topology: star at tiny P, hier when nodes > 1, else tree |||
//!
//! Every bulk data path — the ring pipelines, the tree/hier bundle
//! forwarding, and the elimination allreduce — rides the shared
//! [`ChunkStream`](crate::comm::ChunkStream) datapath: pooled frame
//! buffers, the 16-bit chunk cap enforced once, and zero
//! re-serialization on forwarding hops.
//!
//! All operations run over the existing [`Transport`] trait, are
//! dtype-generic over [`Element`], and tag their messages in the
//! [`NS_COLL`](crate::comm::tags::NS_COLL) namespace (legacy call
//! sites keep their historical namespaces — see [`TagSpace`]).
//!
//! **Deterministic reductions.** Reduction contributions travel
//! *unreduced* and are folded at the destination in PID order, so
//! every algorithm — star, tree, ring, hierarchical — produces
//! **bit-identical** results, including non-associative f32/f64 sums.
//! The cost is O(P·n) payload at the root instead of O(n) per link,
//! which is the right trade for the scalar/control-plane reductions
//! these calls serve (`sum(A)`, result aggregation); bulk data moves
//! through the remap engine, not through reductions. Long-vector
//! allreduces that can waive exact fold order opt in to the
//! elimination schedule with [`AllreduceOrder::Fast`] (see
//! [`Collective::allreduce_ordered`]): `(P−1)/P·2n` bytes per rank,
//! elected by `auto` contexts above [`ELIM_THRESHOLD_BYTES`].
//!
//! The subsystem is selected end-to-end by `repro run --coll
//! {star,tree,ring,hier,auto}` (threaded through
//! [`RunConfig`](crate::coordinator::RunConfig) like the backend
//! axis) and measured by `repro bench-collective`
//! (`bench_collective_v1` documents: latency, bytes, and message
//! counts per algorithm vs P).

mod hier;
mod ring;
mod star;
mod topology;
mod tree;

pub use topology::Topology;

use crate::comm::datapath::{self, ChunkStream, ChunkTag};
use crate::comm::{tags, CommError, Result, Tag, Transport};
use crate::dmap::Pid;
use crate::element::Element;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Phase ids for the packed step field (bits 16..20): keeps the
/// gather and broadcast halves of one collective call, the up and
/// down halves of a barrier, and the two phases of the elimination
/// allreduce in disjoint tag streams.
pub(crate) const PH_GATHER: u64 = 0;
pub(crate) const PH_BCAST: u64 = 1;
pub(crate) const PH_UP: u64 = 2;
pub(crate) const PH_DOWN: u64 = 3;
pub(crate) const PH_DISSEM: u64 = 4;
/// Reduce-scatter phase of the elimination allreduce.
pub(crate) const PH_RS: u64 = 5;
/// Allgather phase of the elimination allreduce.
pub(crate) const PH_AG: u64 = 6;

/// `ceil(log2(p))` — the round count of every logarithmic schedule.
pub(crate) fn log2_rounds(p: usize) -> u32 {
    if p <= 1 {
        0
    } else {
        usize::BITS - (p - 1).leading_zeros()
    }
}

/// Which collective algorithm family executes an operation — the
/// `--coll` axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollKind {
    /// Leader-centric reference (the pre-subsystem wire behavior).
    Star,
    /// Binomial tree: log-depth, P−1 messages.
    Tree,
    /// Pipeline chain / dissemination: bandwidth-oriented.
    Ring,
    /// Two-level topology-aware composition (star in-node, tree
    /// across node leaders).
    Hier,
    /// Resolve per topology at construction time.
    Auto,
}

impl CollKind {
    pub fn parse(s: &str) -> Option<CollKind> {
        match s {
            "star" => Some(CollKind::Star),
            "tree" => Some(CollKind::Tree),
            "ring" => Some(CollKind::Ring),
            "hier" => Some(CollKind::Hier),
            "auto" => Some(CollKind::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CollKind::Star => "star",
            CollKind::Tree => "tree",
            CollKind::Ring => "ring",
            CollKind::Hier => "hier",
            CollKind::Auto => "auto",
        }
    }

    /// The CLI wording of the valid choices.
    pub fn choices() -> &'static str {
        "star|tree|ring|hier|auto"
    }

    /// Stable wire code (RunConfig encoding).
    pub fn code(&self) -> u8 {
        match self {
            CollKind::Star => 0,
            CollKind::Tree => 1,
            CollKind::Ring => 2,
            CollKind::Hier => 3,
            CollKind::Auto => 4,
        }
    }

    pub fn from_code(c: u8) -> Option<CollKind> {
        match c {
            0 => Some(CollKind::Star),
            1 => Some(CollKind::Tree),
            2 => Some(CollKind::Ring),
            3 => Some(CollKind::Hier),
            4 => Some(CollKind::Auto),
            _ => None,
        }
    }
}

impl std::fmt::Display for CollKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The tag coordinates of one collective call.
///
/// Multi-round algorithms pack their messages as
/// `(ns, epoch, level|phase|round)` via [`tags::pack`]; the **star**
/// algorithm always uses a single tag — by default
/// `pack(ns, epoch, 0)`, or an explicit legacy constant
/// ([`TagSpace::with_star_tag`]) so rewired call sites reproduce
/// their pre-subsystem wire tags bit-for-bit under `--coll star`
/// (the coordinator's `CONFIG`/`RESULT` control tags).
#[derive(Debug, Clone, Copy)]
pub struct TagSpace {
    ns: u8,
    epoch: u64,
    star_tag: Tag,
}

impl TagSpace {
    /// A packed tag space in namespace `ns` (star uses step 0 —
    /// identical to the legacy packed tags of reduce/agg/barrier).
    pub fn packed(ns: u8, epoch: u64) -> TagSpace {
        TagSpace { ns, epoch, star_tag: tags::pack(ns, epoch, 0) }
    }

    /// A packed tag space whose star-algorithm tag is the legacy
    /// constant `star` (non-star algorithms still pack in `ns`).
    pub fn with_star_tag(ns: u8, epoch: u64, star: Tag) -> TagSpace {
        TagSpace { ns, epoch, star_tag: star }
    }

    /// The single tag the star algorithm uses.
    pub(crate) fn star(&self) -> Tag {
        self.star_tag
    }

    /// The packed tag of `(level, phase, round)`. Levels separate the
    /// hierarchical composition's phases, phases separate the halves
    /// of one operation, rounds separate a schedule's steps. A
    /// collective call runs one algorithm world-wide (SPMD), so the
    /// star tag and packed steps can never meet on a wire.
    pub(crate) fn at(&self, level: u64, phase: u64, round: u64) -> Tag {
        debug_assert!(level < 16 && phase < 16 && round < (1 << 16));
        tags::pack(self.ns, self.epoch, (level << 20) | (phase << 16) | round)
    }

    /// The [`ChunkTag`] of one `(level, phase)` lane — the datapath
    /// stream coordinates of a chunked collective data path (the
    /// 16-bit round field carries the chunk index).
    pub(crate) fn chunk_tag(&self, level: u64, phase: u64) -> ChunkTag {
        debug_assert!(level < 16 && phase < 16);
        ChunkTag::with_lane(self.ns, self.epoch, (level << 20) | (phase << 16))
    }
}

/// A binary reduction operator, dtype-generic over the sealed
/// [`Element`] set (no round-trip through f64 — `DarrayT<i64>` sums
/// wrap exactly, `DarrayT<f32>` reduces in f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    /// The operator's identity element for `T`.
    #[inline]
    pub fn identity<T: Element>(&self) -> T {
        match self {
            ReduceOp::Sum => T::ZERO,
            ReduceOp::Min => T::MAX_BOUND,
            ReduceOp::Max => T::MIN_BOUND,
        }
    }

    /// Combine two values (wrapping sums for integers, IEEE min/max
    /// for floats — matching the legacy f64 behavior at `T = f64`).
    #[inline]
    pub fn combine<T: Element>(&self, a: T, b: T) -> T {
        match self {
            ReduceOp::Sum => T::add(a, b),
            ReduceOp::Min => T::elem_min(a, b),
            ReduceOp::Max => T::elem_max(a, b),
        }
    }
}

/// Framed rank-keyed byte bundles — the wire currency of the tree
/// and hierarchical gathers: `[n] n × ([rank][len][bytes])`.
pub(crate) mod bundle {
    use crate::comm::datapath::{self, ChunkStream, ChunkTag};
    use crate::comm::{CommError, Result, WireReader, WireWriter};

    pub(crate) fn write<B: AsRef<[u8]>>(entries: &[(u64, B)]) -> Vec<u8> {
        let total: usize = entries.iter().map(|(_, b)| 24 + b.as_ref().len()).sum();
        let mut w = WireWriter::with_capacity(8 + total);
        w.put_u64(entries.len() as u64);
        for (rank, bytes) in entries {
            w.put_u64(*rank);
            w.put_bytes(bytes.as_ref());
        }
        w.finish()
    }

    pub(crate) fn read(payload: &[u8], into: &mut Vec<(u64, Vec<u8>)>) -> Result<()> {
        let mut rd = WireReader::new(payload);
        let n = rd.get_usize()?;
        into.reserve(n);
        for _ in 0..n {
            let rank = rd.get_u64()?;
            into.push((rank, rd.get_bytes()?.to_vec()));
        }
        if rd.remaining() != 0 {
            return Err(CommError::Malformed(format!(
                "bundle carries {} trailing bytes",
                rd.remaining()
            )));
        }
        Ok(())
    }

    /// Sort accumulated entries by rank and check they cover
    /// `0..p` exactly once each.
    pub(crate) fn into_rank_order(
        mut acc: Vec<(u64, Vec<u8>)>,
        p: usize,
    ) -> Result<Vec<Vec<u8>>> {
        acc.sort_by_key(|(r, _)| *r);
        if acc.len() != p || acc.iter().enumerate().any(|(i, (r, _))| *r != i as u64) {
            return Err(CommError::Malformed(format!(
                "gather covered {} of {p} ranks",
                acc.len()
            )));
        }
        Ok(acc.into_iter().map(|(_, b)| b).collect())
    }

    /// An accumulating bundle that **never re-serializes**: the local
    /// part stays structured (its `[rank][len]` prefix is written
    /// into the pooled stream frame at send time), and absorbed child
    /// bundles are retained as raw payloads. Forwarding up a tree
    /// sends `[count][own prefix] + part + payloads` as a slice list
    /// through the shared datapath — every payload byte is encoded at
    /// its origin and then only windowed by [`ChunkStream::send`],
    /// which is what kills the old per-hop `write(&acc)` rebuild
    /// (O(subtree) bytes re-encoded at every level). The wire layout
    /// is byte-identical to [`write`]'s.
    pub(crate) struct Acc {
        count: u64,
        own_rank: u64,
        own_part: Vec<u8>,
        /// Raw absorbed child bundles (`[n] entries…`, as received).
        absorbed: Vec<Vec<u8>>,
    }

    impl Acc {
        /// Start a bundle holding this rank's own part.
        pub(crate) fn new(rank: u64, part: Vec<u8>) -> Acc {
            Acc { count: 1, own_rank: rank, own_part: part, absorbed: Vec::new() }
        }

        /// Absorb a received bundle payload (raw `[n] entries…`
        /// bytes) without parsing or copying its entries.
        pub(crate) fn absorb(&mut self, payload: Vec<u8>) -> Result<()> {
            if payload.len() < 8 {
                return Err(CommError::Malformed(format!(
                    "bundle payload is {} bytes, needs an 8-byte count",
                    payload.len()
                )));
            }
            let n = u64::from_le_bytes(payload[..8].try_into().unwrap());
            self.count += n;
            self.absorbed.push(payload);
            Ok(())
        }

        /// Stream this bundle to `to` — the forwarding hop of a
        /// tree/hierarchical gather. The 24-byte
        /// `[count][rank][len]` head is the only bytes written here;
        /// the part and every absorbed bundle ride as windows.
        pub(crate) fn send(
            &self,
            t: &dyn crate::comm::Transport,
            to: crate::dmap::Pid,
            tag: ChunkTag,
            chunk_bytes: usize,
        ) -> Result<()> {
            let mut head = datapath::checkout(24);
            let mut w = WireWriter::from_vec(head.take());
            w.put_u64(self.count);
            w.put_u64(self.own_rank);
            w.put_u64(self.own_part.len() as u64);
            head.restore(w.finish());
            let mut parts: Vec<&[u8]> = Vec::with_capacity(2 + self.absorbed.len());
            parts.push(head.as_slice());
            parts.push(&self.own_part);
            for payload in &self.absorbed {
                parts.push(&payload[8..]);
            }
            ChunkStream::send(t, to, tag, chunk_bytes, &parts)?;
            Ok(())
        }

        /// Root side: collect the accumulated entries and return the
        /// parts in rank order. The own part moves without a copy and
        /// each absorbed bundle is parsed in place — no flattening
        /// pass, one copy per received entry.
        pub(crate) fn into_rank_order(self, p: usize) -> Result<Vec<Vec<u8>>> {
            let mut entries = Vec::with_capacity(self.count as usize);
            entries.push((self.own_rank, self.own_part));
            for payload in &self.absorbed {
                read(payload, &mut entries)?;
            }
            into_rank_order(entries, p)
        }
    }
}

/// Default pipeline chunk for the chunked data paths (the shared
/// datapath default; override per run with `--chunk-bytes`).
pub const DEFAULT_CHUNK_BYTES: usize = datapath::DEFAULT_CHUNK_BYTES;

/// Vector-allreduce size (n · width · P bytes) above which
/// [`CollKind::Auto`] elects the elimination (reduce-scatter +
/// allgather) schedule — when the caller has waived exact fold order
/// with [`AllreduceOrder::Fast`]. Below it the order-preserving
/// gather-fold wins on latency.
pub const ELIM_THRESHOLD_BYTES: usize = 4 << 20;

/// Whether an allreduce must reproduce the star reference bit-for-bit
/// or may trade fold order for bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllreduceOrder {
    /// Contributions fold in PID order — bit-identical to the star
    /// reference for every dtype, including f32/f64 sums. The
    /// default.
    #[default]
    Deterministic,
    /// The caller waives exact fold order: [`CollKind::Auto`] may
    /// elect the elimination (reduce-scatter + allgather) schedule
    /// for long vectors, whose `(P−1)/P·2n` bytes per rank replace
    /// the gather-fold's O(P·n) at the root. Exact for wrapping
    /// integer sums and all min/max; f32/f64 sums differ from star
    /// only by association order (numerical tolerance).
    Fast,
}

/// A configured collective context: a resolved algorithm family plus
/// the launch [`Topology`]. Cheap to construct; hold one per run.
#[derive(Debug, Clone)]
pub struct Collective {
    kind: CollKind,
    /// The request was [`CollKind::Auto`] — the context may elect the
    /// elimination allreduce when the caller waives fold order.
    auto: bool,
    topo: Topology,
    chunk_bytes: usize,
    elim_threshold: usize,
    /// Fold elimination-allreduce segments chunk-by-chunk as they
    /// land instead of after the whole segment is reassembled.
    overlap: bool,
}

impl Collective {
    /// Build a context, resolving [`CollKind::Auto`] against the
    /// topology: tiny worlds stay star (lowest constant), multi-node
    /// topologies go hierarchical, flat big worlds go tree.
    pub fn new(kind: CollKind, topo: Topology) -> Collective {
        let auto = kind == CollKind::Auto;
        let kind = match kind {
            CollKind::Auto => {
                let np = topo.np();
                if np <= 4 {
                    CollKind::Star
                } else if topo.node_count() > 1 && np > topo.node_count() {
                    CollKind::Hier
                } else {
                    CollKind::Tree
                }
            }
            k => k,
        };
        Collective {
            kind,
            auto,
            topo,
            chunk_bytes: datapath::ambient_chunk_bytes(),
            elim_threshold: ELIM_THRESHOLD_BYTES,
            overlap: true,
        }
    }

    /// The star reference over a flat world — the control-plane
    /// bootstrap context (config broadcast) and the legacy default.
    pub fn star(np: usize) -> Collective {
        Collective::new(CollKind::Star, Topology::flat(np))
    }

    /// Override the pipeline chunk size of this context's ring data
    /// paths (tests force multi-chunk pipelines with tiny payloads;
    /// bundle and remap streams follow the process-wide
    /// `--chunk-bytes` instead).
    pub fn with_chunk_bytes(mut self, chunk_bytes: usize) -> Collective {
        self.chunk_bytes = chunk_bytes.max(1);
        self
    }

    /// Override the elimination-allreduce threshold (tests force the
    /// reduce-scatter schedule with tiny vectors).
    pub fn with_elim_threshold(mut self, bytes: usize) -> Collective {
        self.elim_threshold = bytes;
        self
    }

    /// Toggle compute-on-arrival for the elimination allreduce
    /// (default on): each reduce-scatter chunk is folded — and each
    /// allgather chunk decoded into place — the moment it lands, so
    /// the combine of chunk `k` overlaps the wire of chunk `k+1`.
    /// The per-element fold is identical to the reassembled path, so
    /// results are bit-identical either way; `false` restores the
    /// whole-segment receive (the bench's serial reference).
    pub fn with_overlap(mut self, overlap: bool) -> Collective {
        self.overlap = overlap;
        self
    }

    /// The resolved algorithm (never `Auto`).
    pub fn kind(&self) -> CollKind {
        self.kind
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    fn member_index(group: &[Pid], pid: Pid) -> Result<usize> {
        group.iter().position(|&p| p == pid).ok_or_else(|| {
            CommError::Malformed(format!("pid {pid} is not a member of the collective group"))
        })
    }

    fn world(t: &dyn Transport) -> Vec<Pid> {
        (0..t.np()).collect()
    }

    /// Broadcast `payload` from PID `world[0]` to the whole world;
    /// every PID returns the payload.
    pub fn bcast(&self, t: &dyn Transport, space: TagSpace, payload: Vec<u8>) -> Result<Vec<u8>> {
        self.bcast_group(t, space, &Self::world(t), payload)
    }

    /// Broadcast within an explicit participant `group` (root =
    /// `group[0]`; only the root's `payload` is read).
    pub fn bcast_group(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        payload: Vec<u8>,
    ) -> Result<Vec<u8>> {
        if group.len() <= 1 {
            return Ok(payload);
        }
        let t0 = crate::obs::span_begin();
        let me = Self::member_index(group, t.pid())?;
        let out = match self.kind {
            CollKind::Star => star::bcast(t, group, me, space.star(), payload),
            CollKind::Tree => tree::bcast(t, group, me, &space, 0, payload),
            CollKind::Ring => ring::bcast(t, group, me, &space, 0, self.chunk_bytes, payload),
            CollKind::Hier => hier::bcast(t, &self.topo, group, t.pid(), &space, payload),
            CollKind::Auto => unreachable!("resolved at construction"),
        }?;
        crate::obs_span!(
            crate::obs::EventKind::CollOp,
            t0,
            tag: space.at(0, PH_BCAST, 0),
            peer: crate::obs::NO_PEER,
            a: out.len() as u64,
            b: group.len() as u64
        );
        Ok(out)
    }

    /// Gather every PID's `part` to PID 0: `Some(parts)` in PID order
    /// at the root, `None` elsewhere.
    pub fn gather(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        part: Vec<u8>,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        self.gather_group(t, space, &Self::world(t), part)
    }

    /// Gather within an explicit participant `group` (root =
    /// `group[0]`; parts returned in group-rank order).
    pub fn gather_group(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        part: Vec<u8>,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        if group.len() <= 1 {
            return Ok(Some(vec![part]));
        }
        let t0 = crate::obs::span_begin();
        let part_bytes = part.len() as u64;
        let me = Self::member_index(group, t.pid())?;
        let out = match self.kind {
            CollKind::Star => star::gather(t, group, me, space.star(), part),
            CollKind::Tree => {
                tree::gather(t, group, me, &space, 0, datapath::ambient_chunk_bytes(), part)
            }
            CollKind::Ring => ring::gather(t, group, me, &space, 0, self.chunk_bytes, part),
            CollKind::Hier => hier::gather(t, &self.topo, group, t.pid(), &space, part),
            CollKind::Auto => unreachable!("resolved at construction"),
        }?;
        let bytes = match &out {
            Some(parts) => parts.iter().map(|p| p.len() as u64).sum(),
            None => part_bytes,
        };
        crate::obs_span!(
            crate::obs::EventKind::CollOp,
            t0,
            tag: space.at(0, PH_GATHER, 0),
            peer: crate::obs::NO_PEER,
            a: bytes,
            b: group.len() as u64
        );
        Ok(out)
    }

    /// Allgather: every PID returns every PID's `part`, in rank
    /// order. Composition: gather to the root, broadcast the bundle.
    pub fn allgather(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        part: Vec<u8>,
    ) -> Result<Vec<Vec<u8>>> {
        self.allgather_group(t, space, &Self::world(t), part)
    }

    pub fn allgather_group(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        part: Vec<u8>,
    ) -> Result<Vec<Vec<u8>>> {
        if group.len() <= 1 {
            return Ok(vec![part]);
        }
        let gathered = self.gather_group(t, space, group, part)?;
        let encoded = match &gathered {
            Some(parts) => {
                let entries: Vec<(u64, &[u8])> = parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| (i as u64, p.as_slice()))
                    .collect();
                bundle::write(&entries)
            }
            None => Vec::new(),
        };
        let bytes = self.bcast_group(t, space, group, encoded)?;
        let mut acc = Vec::new();
        bundle::read(&bytes, &mut acc)?;
        bundle::into_rank_order(acc, group.len())
    }

    /// Element-wise reduction of equal-length local vectors to PID 0:
    /// `Some(reduced)` at the root, `None` elsewhere. Contributions
    /// are folded **in rank order** (see the module docs), so the
    /// result is bit-identical across algorithms.
    pub fn reduce<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        local: &[T],
        op: ReduceOp,
    ) -> Result<Option<Vec<T>>> {
        self.reduce_group(t, space, &Self::world(t), local, op)
    }

    pub fn reduce_group<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        local: &[T],
        op: ReduceOp,
    ) -> Result<Option<Vec<T>>> {
        let mut part = Vec::with_capacity(local.len() * T::WIDTH);
        T::copy_to_le(local, &mut part);
        let Some(parts) = self.gather_group(t, space, group, part)? else {
            return Ok(None);
        };
        let mut acc = local.to_vec();
        let mut other = vec![T::ZERO; acc.len()];
        for p in &parts[1..] {
            if p.len() != acc.len() * T::WIDTH {
                return Err(CommError::Malformed(format!(
                    "reduce contribution is {} bytes, expected {} ({} × {})",
                    p.len(),
                    acc.len() * T::WIDTH,
                    acc.len(),
                    T::WIDTH
                )));
            }
            T::copy_from_le(p, &mut other);
            for (a, b) in acc.iter_mut().zip(&other) {
                *a = op.combine(*a, *b);
            }
        }
        Ok(Some(acc))
    }

    /// Reduction whose result lands on every PID (reduce + broadcast;
    /// under star this is bit-for-bit the legacy `allreduce` wire
    /// exchange).
    pub fn allreduce<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        local: &[T],
        op: ReduceOp,
    ) -> Result<Vec<T>> {
        self.allreduce_group(t, space, &Self::world(t), local, op)
    }

    pub fn allreduce_group<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        local: &[T],
        op: ReduceOp,
    ) -> Result<Vec<T>> {
        if group.len() <= 1 {
            return Ok(local.to_vec());
        }
        let reduced = self.reduce_group(t, space, group, local, op)?;
        let bytes = match &reduced {
            Some(v) => {
                let mut b = Vec::with_capacity(v.len() * T::WIDTH);
                T::copy_to_le(v, &mut b);
                b
            }
            None => Vec::new(),
        };
        let out = self.bcast_group(t, space, group, bytes)?;
        if out.len() != local.len() * T::WIDTH {
            return Err(CommError::Malformed(format!(
                "allreduce result is {} bytes, expected {}",
                out.len(),
                local.len() * T::WIDTH
            )));
        }
        let mut res = vec![T::ZERO; local.len()];
        T::copy_from_le(&out, &mut res);
        Ok(res)
    }

    /// Scalar allreduce — the `sum(A)`/`min(A)`/`max(A)` shape.
    pub fn allreduce_scalar<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        local: T,
        op: ReduceOp,
    ) -> Result<T> {
        Ok(self.allreduce(t, space, &[local], op)?[0])
    }

    /// Allreduce with an explicit order contract: under
    /// [`AllreduceOrder::Deterministic`] this is exactly
    /// [`Collective::allreduce`]; under [`AllreduceOrder::Fast`] a
    /// context built from [`CollKind::Auto`] elects the elimination
    /// (reduce-scatter + allgather) schedule once
    /// `n · width · P` crosses the threshold — the ROADMAP's
    /// long-vector mode, `(P−1)/P·2n` bytes per rank instead of
    /// O(P·n) at the root.
    pub fn allreduce_ordered<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        local: &[T],
        op: ReduceOp,
        order: AllreduceOrder,
    ) -> Result<Vec<T>> {
        let group = Self::world(t);
        if self.elects_elimination::<T>(local.len(), group.len(), order) {
            self.allreduce_elim_group(t, space, &group, local, op)
        } else {
            self.allreduce_group(t, space, &group, local, op)
        }
    }

    /// Does this context route an `n`-element, `p`-rank allreduce
    /// through the elimination schedule? Only when the request was
    /// `auto`, the caller waived exact order, every rank gets a
    /// non-empty segment, and the aggregate size clears the
    /// threshold.
    fn elects_elimination<T: Element>(&self, n: usize, p: usize, order: AllreduceOrder) -> bool {
        self.auto
            && order == AllreduceOrder::Fast
            && p > 1
            && n >= p
            && n.saturating_mul(T::WIDTH).saturating_mul(p) >= self.elim_threshold
    }

    /// The elimination allreduce: a ring **reduce-scatter** (after
    /// step `s`, rank `i` has combined the incoming partial of
    /// segment `(i − s − 1) mod P` into its copy; after `P−1` steps
    /// rank `i` owns the fully reduced segment `(i + 1) mod P`)
    /// followed by a ring **allgather** of the reduced segments.
    /// Every rank moves `2·(P−1)/P·n` elements instead of the
    /// gather-fold's O(P·n) at the root; segments travel as pooled
    /// [`ChunkStream`]s. Fold order follows the ring, so wrapping
    /// integer sums and min/max are exact while float sums carry
    /// reassociation error — which is why this path requires the
    /// [`AllreduceOrder::Fast`] waiver (it is public so benches and
    /// tests can target the schedule directly).
    pub fn allreduce_elim_group<T: Element>(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        local: &[T],
        op: ReduceOp,
    ) -> Result<Vec<T>> {
        let p = group.len();
        let n = local.len();
        if p <= 1 {
            return Ok(local.to_vec());
        }
        if n < p {
            // Degenerate segments: the ordered path handles it.
            return self.allreduce_group(t, space, group, local, op);
        }
        let t0 = crate::obs::span_begin();
        let me = Self::member_index(group, t.pid())?;
        let next = group[(me + 1) % p];
        let prev = group[(me + p - 1) % p];
        let seg = |k: usize| (k * n / p, (k + 1) * n / p);
        let mut acc = local.to_vec();
        let mut incoming: Vec<T> = Vec::new();
        let rs_tag = space.chunk_tag(0, PH_RS);
        let ag_tag = space.chunk_tag(0, PH_AG);
        // Phase 1 — reduce-scatter. All sends to `next` share one tag
        // lane: the transport's per-(src, dst, tag) FIFO sequences the
        // steps. With overlap on, each landed chunk is folded while
        // `prev` is still pushing the next one; the serial fallback
        // reassembles the whole segment first and folds after (same
        // per-element combine, bit-identical result).
        for s in 0..p - 1 {
            // Per-round span (round index is 1-based; round 0 is the
            // phase summary below): the round boundaries are what the
            // straggler analysis aligns across ranks.
            let r0 = crate::obs::span_begin();
            let (slo, shi) = seg((me + p - s) % p);
            Self::send_segment(t, next, rs_tag, self.chunk_bytes, &acc[slo..shi])?;
            let (rlo, rhi) = seg((me + p - s - 1) % p);
            if self.overlap {
                Self::recv_segment_fold(t, prev, rs_tag, &mut acc[rlo..rhi], op, &mut incoming)?;
            } else {
                incoming.resize(rhi - rlo, T::ZERO);
                Self::recv_segment_into(t, prev, rs_tag, &mut incoming)?;
                for (a, b) in acc[rlo..rhi].iter_mut().zip(&incoming) {
                    *a = op.combine(*b, *a);
                }
            }
            crate::obs_span!(
                crate::obs::EventKind::CollOp,
                r0,
                tag: space.at(0, PH_RS, (s + 1) as u64),
                peer: crate::obs::NO_PEER,
                a: ((shi - slo) * T::WIDTH) as u64,
                b: (s + 1) as u64
            );
        }
        // Phase 2 — allgather: forward the segment received last
        // step, starting from the fully reduced one this rank owns;
        // received segments decode straight into their final slot
        // (chunk by chunk when overlap is on).
        for s in 0..p - 1 {
            let r0 = crate::obs::span_begin();
            let (slo, shi) = seg((me + 1 + p - s) % p);
            Self::send_segment(t, next, ag_tag, self.chunk_bytes, &acc[slo..shi])?;
            let (rlo, rhi) = seg((me + p - s) % p);
            if self.overlap {
                Self::recv_segment_streamed(t, prev, ag_tag, &mut acc[rlo..rhi])?;
            } else {
                Self::recv_segment_into(t, prev, ag_tag, &mut acc[rlo..rhi])?;
            }
            crate::obs_span!(
                crate::obs::EventKind::CollOp,
                r0,
                tag: space.at(0, PH_AG, (s + 1) as u64),
                peer: crate::obs::NO_PEER,
                a: ((shi - slo) * T::WIDTH) as u64,
                b: (s + 1) as u64
            );
        }
        crate::obs_span!(
            crate::obs::EventKind::CollOp,
            t0,
            tag: space.at(0, PH_RS, 0),
            peer: crate::obs::NO_PEER,
            a: (n * T::WIDTH) as u64,
            b: p as u64
        );
        Ok(acc)
    }

    /// Stream one typed segment: on little-endian targets the
    /// segment's in-memory bytes are windowed straight onto the wire
    /// (no staging copy at all — [`Element::as_le_bytes`]); the
    /// big-endian fallback encodes into a pooled buffer.
    fn send_segment<T: Element>(
        t: &dyn Transport,
        to: Pid,
        tag: ChunkTag,
        chunk_bytes: usize,
        seg: &[T],
    ) -> Result<()> {
        if let Some(bytes) = T::as_le_bytes(seg) {
            ChunkStream::send(t, to, tag, chunk_bytes, &[bytes])?;
            return Ok(());
        }
        let mut buf = datapath::checkout(seg.len() * T::WIDTH);
        let mut bytes = buf.take();
        T::copy_to_le(seg, &mut bytes);
        buf.restore(bytes);
        ChunkStream::send(t, to, tag, chunk_bytes, &[buf.as_slice()])?;
        Ok(())
    }

    /// Receive one typed segment of exactly `dst.len()` elements,
    /// decoding straight into `dst` (one bulk memcpy on LE targets).
    fn recv_segment_into<T: Element>(
        t: &dyn Transport,
        from: Pid,
        tag: ChunkTag,
        dst: &mut [T],
    ) -> Result<()> {
        let bytes = ChunkStream::recv(t, from, tag)?;
        if bytes.len() != dst.len() * T::WIDTH {
            return Err(CommError::Malformed(format!(
                "elimination segment is {} bytes, expected {} ({} × {})",
                bytes.len(),
                dst.len() * T::WIDTH,
                dst.len(),
                T::WIDTH
            )));
        }
        T::copy_from_le(&bytes, dst);
        Ok(())
    }

    /// Size check shared by the streaming segment receivers: the
    /// stream frame's `total` must match the expected segment exactly.
    fn check_segment_bytes<T: Element>(total: usize, elems: usize) -> Result<()> {
        if total != elems * T::WIDTH {
            return Err(CommError::Malformed(format!(
                "elimination segment is {} bytes, expected {} ({} × {})",
                total,
                elems * T::WIDTH,
                elems,
                T::WIDTH
            )));
        }
        Ok(())
    }

    /// Compute-on-arrival reduce-scatter receive: fold each chunk of
    /// the incoming segment into `acc` the moment it lands, so the
    /// combine of chunk `k` overlaps the wire of chunk `k+1`. Chunk
    /// boundaries need not align with elements — a split element is
    /// completed through a tiny carry buffer — and elements are folded
    /// in order with the same `combine(incoming, local)` orientation as
    /// the reassembled path, so the result is bit-identical to
    /// [`Collective::recv_segment_into`] + fold. `scratch` is the
    /// caller's reusable decode buffer (grown to at most one chunk).
    fn recv_segment_fold<T: Element>(
        t: &dyn Transport,
        from: Pid,
        tag: ChunkTag,
        acc: &mut [T],
        op: ReduceOp,
        scratch: &mut Vec<T>,
    ) -> Result<()> {
        let width = T::WIDTH;
        let mut carry = [0u8; 16];
        let mut carry_len = 0usize;
        let mut pos = 0usize;
        ChunkStream::drain_chunks(t, &[from], tag, |c| {
            if c.chunk_idx == 0 {
                Self::check_segment_bytes::<T>(c.total, acc.len())?;
            }
            let mut bytes = c.payload();
            if carry_len > 0 {
                let take = (width - carry_len).min(bytes.len());
                carry[carry_len..carry_len + take].copy_from_slice(&bytes[..take]);
                carry_len += take;
                bytes = &bytes[take..];
                if carry_len == width {
                    let mut one = [T::ZERO];
                    T::copy_from_le(&carry[..width], &mut one);
                    acc[pos] = op.combine(one[0], acc[pos]);
                    pos += 1;
                    carry_len = 0;
                }
            }
            let n = bytes.len() / width;
            if n > 0 {
                scratch.resize(n, T::ZERO);
                T::copy_from_le(&bytes[..n * width], &mut scratch[..n]);
                for (a, b) in acc[pos..pos + n].iter_mut().zip(&scratch[..n]) {
                    *a = op.combine(*b, *a);
                }
                pos += n;
            }
            let rem = bytes.len() - n * width;
            if rem > 0 {
                carry[..rem].copy_from_slice(&bytes[n * width..]);
                carry_len = rem;
            }
            Ok(())
        })
    }

    /// Compute-on-arrival allgather receive: decode each chunk of the
    /// incoming segment straight into its final slot in `dst` as it
    /// lands (split elements complete through the carry buffer, same
    /// as [`Collective::recv_segment_fold`]).
    fn recv_segment_streamed<T: Element>(
        t: &dyn Transport,
        from: Pid,
        tag: ChunkTag,
        dst: &mut [T],
    ) -> Result<()> {
        let width = T::WIDTH;
        let mut carry = [0u8; 16];
        let mut carry_len = 0usize;
        let mut pos = 0usize;
        ChunkStream::drain_chunks(t, &[from], tag, |c| {
            if c.chunk_idx == 0 {
                Self::check_segment_bytes::<T>(c.total, dst.len())?;
            }
            let mut bytes = c.payload();
            if carry_len > 0 {
                let take = (width - carry_len).min(bytes.len());
                carry[carry_len..carry_len + take].copy_from_slice(&bytes[..take]);
                carry_len += take;
                bytes = &bytes[take..];
                if carry_len == width {
                    T::copy_from_le(&carry[..width], &mut dst[pos..pos + 1]);
                    pos += 1;
                    carry_len = 0;
                }
            }
            let n = bytes.len() / width;
            if n > 0 {
                T::copy_from_le(&bytes[..n * width], &mut dst[pos..pos + n]);
                pos += n;
            }
            let rem = bytes.len() - n * width;
            if rem > 0 {
                carry[..rem].copy_from_slice(&bytes[n * width..]);
                carry_len = rem;
            }
            Ok(())
        })
    }

    /// Barrier over the whole world.
    pub fn barrier(&self, t: &dyn Transport, space: TagSpace, timeout: Duration) -> Result<()> {
        self.barrier_group(t, space, &Self::world(t), timeout)
    }

    pub fn barrier_group(
        &self,
        t: &dyn Transport,
        space: TagSpace,
        group: &[Pid],
        timeout: Duration,
    ) -> Result<()> {
        if group.len() <= 1 {
            return Ok(());
        }
        let t0 = crate::obs::span_begin();
        let me = Self::member_index(group, t.pid())?;
        match self.kind {
            CollKind::Star => star::barrier(t, group, me, space.star(), timeout),
            CollKind::Tree => tree::barrier(t, group, me, &space, 0, timeout),
            CollKind::Ring => ring::barrier(t, group, me, &space, 0, timeout),
            CollKind::Hier => hier::barrier(t, &self.topo, group, t.pid(), &space, timeout),
            CollKind::Auto => unreachable!("resolved at construction"),
        }?;
        crate::obs_span!(
            crate::obs::EventKind::CollOp,
            t0,
            tag: space.at(0, PH_UP, 0),
            peer: crate::obs::NO_PEER,
            a: 0,
            b: group.len() as u64
        );
        Ok(())
    }
}

/// The process-wide default collective spec `(kind, pids_per_node)`
/// behind the legacy wrappers (`darray::allreduce`, `DarrayT::agg`,
/// `comm::barrier::barrier`). Defaults to `(Star, 0 = flat)` — the
/// exact pre-subsystem behavior; the `repro` binary sets it from
/// `--coll` and the launch triples.
static AMBIENT: Mutex<(CollKind, usize)> = Mutex::new((CollKind::Star, 0));

/// Install the process-default collective algorithm and node width.
pub fn set_ambient(kind: CollKind, pids_per_node: usize) {
    *AMBIENT.lock().unwrap() = (kind, pids_per_node);
}

/// The current process-default `(kind, pids_per_node)`.
pub fn ambient_spec() -> (CollKind, usize) {
    *AMBIENT.lock().unwrap()
}

/// Memoized ambient context: rebuilding a `Topology` (node lists +
/// pid index) per collective call would put O(np) allocations on
/// every iterated reduction; the context is immutable per
/// `(kind, per_node, np, chunk_bytes)` — the datapath chunk size is
/// part of the key so a context cached before `--chunk-bytes` was
/// installed is not served stale — so cache the last one.
#[allow(clippy::type_complexity)]
static AMBIENT_CACHE: Mutex<Option<((CollKind, usize, usize, usize), Arc<Collective>)>> =
    Mutex::new(None);

/// A [`Collective`] for an `np`-wide world under the process default.
pub fn ambient(np: usize) -> Arc<Collective> {
    let (kind, per_node) = ambient_spec();
    let key = (kind, per_node, np, datapath::ambient_chunk_bytes());
    let mut cache = AMBIENT_CACHE.lock().unwrap();
    if let Some((k, c)) = cache.as_ref() {
        if *k == key {
            return c.clone();
        }
    }
    let coll = Arc::new(Collective::new(kind, Topology::grouped(np, per_node)));
    *cache = Some((key, coll.clone()));
    coll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ChannelHub;
    use std::sync::Arc;
    use std::thread;

    const NS_TEST: u8 = tags::NS_COLL;

    fn spmd<R: Send + 'static>(
        np: usize,
        f: impl Fn(&dyn Transport) -> R + Send + Sync + 'static,
    ) -> Vec<R> {
        let world = ChannelHub::world(np);
        let f = Arc::new(f);
        world
            .into_iter()
            .map(|t| {
                let f = f.clone();
                thread::spawn(move || f(&t))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    }

    fn all_kinds() -> [Collective; 4] {
        [
            Collective::new(CollKind::Star, Topology::flat(8)),
            Collective::new(CollKind::Tree, Topology::flat(8)),
            Collective::new(CollKind::Ring, Topology::flat(8)).with_chunk_bytes(16),
            Collective::new(CollKind::Hier, Topology::grouped(8, 3)),
        ]
    }

    #[test]
    fn bcast_delivers_root_payload_every_kind_and_width() {
        for coll in all_kinds() {
            let coll = Arc::new(coll);
            for np in [1usize, 2, 3, 5, 8] {
                for len in [0usize, 1, 37, 4096] {
                    let coll = coll.clone();
                    let out = spmd(np, move |t| {
                        let payload = if t.pid() == 0 {
                            (0..len).map(|i| (i % 251) as u8).collect()
                        } else {
                            Vec::new()
                        };
                        coll.bcast(t, TagSpace::packed(NS_TEST, len as u64), payload).unwrap()
                    });
                    let want: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
                    for got in out {
                        assert_eq!(got, want);
                    }
                }
            }
        }
    }

    #[test]
    fn gather_collects_rank_ordered_parts() {
        for coll in all_kinds() {
            let coll = Arc::new(coll);
            for np in [1usize, 2, 3, 5, 8] {
                let coll = coll.clone();
                let out = spmd(np, move |t| {
                    let part = vec![t.pid() as u8; t.pid() + 1];
                    coll.gather(t, TagSpace::packed(NS_TEST, 90), part).unwrap()
                });
                for (pid, got) in out.into_iter().enumerate() {
                    if pid == 0 {
                        let parts = got.expect("root gets the parts");
                        assert_eq!(parts.len(), np);
                        for (r, p) in parts.iter().enumerate() {
                            assert_eq!(*p, vec![r as u8; r + 1]);
                        }
                    } else {
                        assert!(got.is_none());
                    }
                }
            }
        }
    }

    #[test]
    fn allgather_delivers_everything_everywhere() {
        for coll in all_kinds() {
            let coll = Arc::new(coll);
            let np = 5;
            let out = spmd(np, move |t| {
                coll.allgather(t, TagSpace::packed(NS_TEST, 91), vec![t.pid() as u8 + 10])
                    .unwrap()
            });
            for parts in out {
                assert_eq!(parts.len(), np);
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(*p, vec![r as u8 + 10]);
                }
            }
        }
    }

    #[test]
    fn allreduce_folds_in_rank_order_every_kind() {
        // f64 sums are order-sensitive: rank-order folding must make
        // every algorithm agree with the star reference bitwise.
        for coll in all_kinds() {
            let coll = Arc::new(coll);
            for np in [2usize, 3, 5, 8] {
                let coll = coll.clone();
                let out = spmd(np, move |t| {
                    let local = 0.1 + t.pid() as f64 * 1.7e-3;
                    coll.allreduce_scalar(t, TagSpace::packed(NS_TEST, 92), local, ReduceOp::Sum)
                        .unwrap()
                });
                let want = (0..np).fold(0.0f64, |a, p| a + (0.1 + p as f64 * 1.7e-3));
                for got in out {
                    assert_eq!(got.to_bits(), want.to_bits(), "np={np}");
                }
            }
        }
    }

    #[test]
    fn barrier_completes_every_kind() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for coll in all_kinds() {
            let coll = Arc::new(coll);
            for np in [1usize, 2, 5, 8] {
                let coll = coll.clone();
                let arrived = Arc::new(AtomicUsize::new(0));
                spmd(np, move |t| {
                    arrived.fetch_add(1, Ordering::SeqCst);
                    coll.barrier(t, TagSpace::packed(NS_TEST, 93), Duration::from_secs(10))
                        .unwrap();
                    assert_eq!(arrived.load(Ordering::SeqCst), np);
                    coll.barrier(t, TagSpace::packed(NS_TEST, 94), Duration::from_secs(10))
                        .unwrap();
                });
            }
        }
    }

    #[test]
    fn auto_resolves_by_topology() {
        assert_eq!(Collective::new(CollKind::Auto, Topology::flat(2)).kind(), CollKind::Star);
        assert_eq!(Collective::new(CollKind::Auto, Topology::flat(16)).kind(), CollKind::Tree);
        assert_eq!(
            Collective::new(CollKind::Auto, Topology::grouped(16, 4)).kind(),
            CollKind::Hier
        );
    }

    #[test]
    fn kind_parse_name_code_roundtrip() {
        for k in [CollKind::Star, CollKind::Tree, CollKind::Ring, CollKind::Hier, CollKind::Auto] {
            assert_eq!(CollKind::parse(k.name()), Some(k));
            assert_eq!(CollKind::from_code(k.code()), Some(k));
        }
        assert_eq!(CollKind::parse("mesh"), None);
        assert_eq!(CollKind::from_code(9), None);
    }

    #[test]
    fn log2_rounds_model() {
        assert_eq!(log2_rounds(1), 0);
        assert_eq!(log2_rounds(2), 1);
        assert_eq!(log2_rounds(5), 3);
        assert_eq!(log2_rounds(8), 3);
        assert_eq!(log2_rounds(9), 4);
    }
}
