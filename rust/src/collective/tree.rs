//! Binomial-tree collectives — O(log P) rounds, P−1 messages.
//!
//! Classic doubling schedule over **group ranks** (index in the
//! participant list, root = rank 0):
//!
//! * broadcast — in round `r`, every rank `< 2^r` that holds the
//!   payload sends it to rank `+ 2^r`; after `ceil(log2 P)` rounds
//!   everyone holds it. P−1 messages, log-depth critical path.
//! * gather — the mirror: rank `me` (with `me mod 2^{r+1} == 2^r`)
//!   sends the bundle of its whole binomial subtree to `me − 2^r` as
//!   a chunked stream over the shared datapath. The bundle is a
//!   [`bundle::Acc`]: received child bundles are **forwarded as raw
//!   segments**, never re-parsed or re-encoded, so a multi-MB
//!   aggregation costs each hop O(subtree) memcpy instead of
//!   O(subtree) re-serialization per level. Contributions travel
//!   **unreduced** (see the module docs in [`super`]): the root folds
//!   them in rank order, so every algorithm produces bit-identical
//!   reductions.
//! * barrier — gather-shaped up phase with empty payloads, then a
//!   broadcast-shaped release.

use super::{bundle, log2_rounds, TagSpace, PH_BCAST, PH_DOWN, PH_GATHER, PH_UP};
use crate::comm::datapath::ChunkStream;
use crate::comm::{Result, Transport};
use crate::dmap::Pid;
use std::time::Duration;

/// Binomial broadcast from `group[0]`; every rank returns the payload.
pub(crate) fn bcast(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    payload: Vec<u8>,
) -> Result<Vec<u8>> {
    let p = group.len();
    let mut data = (me == 0).then_some(payload);
    for r in 0..log2_rounds(p) {
        let bit = 1usize << r;
        let tag = space.at(level, PH_BCAST, r as u64);
        if me < bit {
            let dst = me + bit;
            if dst < p {
                t.send(group[dst], tag, data.as_ref().expect("rank < 2^r holds the payload"))?;
            }
        } else if me < 2 * bit {
            data = Some(t.recv(group[me - bit], tag)?);
        }
    }
    Ok(data.expect("every rank holds the payload after the final round"))
}

/// Binomial gather to `group[0]`: returns `Some(parts)` (rank order)
/// at the root, `None` elsewhere. Each rank sends exactly one stream
/// (in its exit round), so the whole schedule shares one
/// `(level, PH_GATHER)` tag lane — `(from, tag)` stays unambiguous —
/// and absorbed subtrees ride upward as raw segments.
pub(crate) fn gather(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    chunk_bytes: usize,
    part: Vec<u8>,
) -> Result<Option<Vec<Vec<u8>>>> {
    let p = group.len();
    let tag = space.chunk_tag(level, PH_GATHER);
    let mut acc = bundle::Acc::new(me as u64, part);
    for r in 0..log2_rounds(p) {
        let bit = 1usize << r;
        if me % (2 * bit) == 0 {
            let src = me + bit;
            if src < p {
                acc.absorb(ChunkStream::recv(t, group[src], tag)?)?;
            }
        } else {
            // me mod 2^{r+1} == 2^r: hand the subtree up and exit.
            acc.send(t, group[me - bit], tag, chunk_bytes)?;
            return Ok(None);
        }
    }
    debug_assert_eq!(me, 0);
    acc.into_rank_order(p).map(Some)
}

/// Tree barrier: binomial up phase (children report) then binomial
/// release, both with empty payloads and the caller's timeout.
pub(crate) fn barrier(
    t: &dyn Transport,
    group: &[Pid],
    me: usize,
    space: &TagSpace,
    level: u64,
    timeout: Duration,
) -> Result<()> {
    let p = group.len();
    for r in 0..log2_rounds(p) {
        let bit = 1usize << r;
        let tag = space.at(level, PH_UP, r as u64);
        if me % (2 * bit) == 0 {
            let src = me + bit;
            if src < p {
                t.recv_timeout(group[src], tag, timeout)?;
            }
        } else {
            t.send(group[me - bit], tag, &[])?;
            break;
        }
    }
    for r in 0..log2_rounds(p) {
        let bit = 1usize << r;
        let tag = space.at(level, PH_DOWN, r as u64);
        if me < bit {
            let dst = me + bit;
            if dst < p {
                t.send(group[dst], tag, &[])?;
            }
        } else if me < 2 * bit {
            t.recv_timeout(group[me - bit], tag, timeout)?;
        }
    }
    Ok(())
}
