//! Bench A2 — map-independence ablation (§IV): global assignment
//! `C(:,:) = A` costs nothing extra when maps align, and pays real
//! communication when they differ.

use distarray::benchx::{bench, report, section};
use distarray::comm::{ChannelHub, Transport};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn spmd_assign(np: usize, n: usize, src_map: fn(usize) -> Dmap, dst_map: fn(usize) -> Dmap) -> u64 {
    let world = ChannelHub::world(np);
    let bytes = Arc::new(AtomicU64::new(0));
    let mut hs = Vec::new();
    for t in world {
        let bytes = bytes.clone();
        hs.push(std::thread::spawn(move || {
            let pid = t.pid();
            let src = Darray::from_global_fn(src_map(np), &[n], pid, |g| g as f64);
            let mut dst = Darray::zeros(dst_map(np), &[n], pid);
            dst.assign_from(&src, &t, 0).unwrap(); // same epoch on every PID
            bytes.fetch_add(t.stats().bytes_sent(), Ordering::Relaxed);
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    bytes.load(Ordering::Relaxed)
}

fn main() {
    let np = 4;
    let n = 1 << 20;

    section("A2 — same-map assign (zero communication)");
    let b0 = spmd_assign(np, n, Dmap::block_1d, Dmap::block_1d);
    println!("bytes on the wire: {b0}");
    assert_eq!(b0, 0, "aligned assign must be communication-free");

    section("A2 — block → cyclic remap (full data movement)");
    let b1 = spmd_assign(np, n, Dmap::block_1d, Dmap::cyclic_1d);
    println!("bytes on the wire: {b1}");
    // 3/4 of elements change owner; each carries 8 bytes + framing.
    assert!(b1 as usize >= n / 2 * 8, "remap should move most of the array");

    section("A2 — wall-clock cost ratio");
    let t_same = bench(1, 5, || spmd_assign(np, n, Dmap::block_1d, Dmap::block_1d));
    let t_remap = bench(1, 5, || spmd_assign(np, n, Dmap::block_1d, Dmap::cyclic_1d));
    report("same-map assign", &t_same, Some(8.0 * n as f64));
    report("block→cyclic remap", &t_remap, Some(8.0 * n as f64));
    println!(
        "remap / same-map time = {:.1}x (the §IV 'significant communication')",
        t_remap.median / t_same.median
    );
    assert!(t_remap.median > t_same.median, "remap must cost more");
    println!("\nablation_remap OK");
}
