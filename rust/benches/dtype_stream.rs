//! Bench D1 — the `--dtype` axis of the STREAM kernels (benchx).
//!
//! Measures the four ops at f32 and f64 over out-of-cache vectors and
//! reports both bytes/sec and elements/sec. The headline check: at
//! roughly equal bytes/sec, f32 triad streams ~2× the elements/sec of
//! f64 (§III formulas with width `W = T::WIDTH`).
//!
//! ```text
//! cargo bench --bench dtype_stream [-- --dtype f32] [-- --log2-n 24]
//! ```
//! With `--dtype` the run is restricted to one dtype; default is the
//! two-dtype comparison.

use distarray::benchx::{bench, report, section, Stats};
use distarray::cli::Args;
use distarray::element::{Dtype, Element};
use distarray::stream::{ops, run_serial_t, STREAM_Q};
use std::hint::black_box;

/// One dtype's kernel sweep; returns (triad stats, bytes per triad run).
fn sweep<T: Element>(n: usize, q: T) -> (Stats, f64) {
    let w = T::WIDTH as f64;
    let bytes_rw2 = 2.0 * w * n as f64; // copy, scale: 1R + 1W
    let bytes_rw3 = 3.0 * w * n as f64; // add, triad: 2R + 1W
    let name = T::DTYPE.name();

    let a = vec![T::from_f64(1.0); n];
    let b = vec![T::from_f64(2.0); n];
    let mut c = vec![T::ZERO; n];
    let mut d = vec![T::ZERO; n];

    let s = bench(2, 9, || ops::copy(black_box(&mut c[..]), black_box(&a)));
    report(&format!("{name} copy"), &s, Some(bytes_rw2));
    let s = bench(2, 9, || ops::scale(black_box(&mut c[..]), black_box(&a), q));
    report(&format!("{name} scale"), &s, Some(bytes_rw2));
    let s = bench(2, 9, || {
        ops::add(black_box(&mut d[..]), black_box(&a), black_box(&b))
    });
    report(&format!("{name} add"), &s, Some(bytes_rw3));
    let s_triad = bench(2, 9, || {
        ops::triad(black_box(&mut d[..]), black_box(&b), black_box(&c), q)
    });
    report(&format!("{name} triad"), &s_triad, Some(bytes_rw3));
    (s_triad, bytes_rw3)
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let log2n = args.flag_usize("log2-n", 24);
    let n = 1usize << log2n;
    let only: Option<Dtype> = match args.flag("dtype") {
        None => None,
        Some(s) => match Dtype::parse(s) {
            Some(d) if d.is_float() => Some(d),
            Some(d) => {
                eprintln!("--dtype {d} has no STREAM sweep here (float dtypes only: f32|f64)");
                std::process::exit(2);
            }
            None => {
                eprintln!("unknown dtype '{s}' (expected f32|f64)");
                std::process::exit(2);
            }
        },
    };

    section(&format!("D1 — dtype axis (n = 2^{log2n}, out-of-cache)"));

    let mut f64_triad: Option<(Stats, f64)> = None;
    let mut f32_triad: Option<(Stats, f64)> = None;
    if only.is_none() || only == Some(Dtype::F64) {
        f64_triad = Some(sweep::<f64>(n, STREAM_Q));
    }
    if only.is_none() || only == Some(Dtype::F32) {
        f32_triad = Some(sweep::<f32>(n, STREAM_Q as f32));
    }

    if let (Some((s64, b64)), Some((s32, b32))) = (&f64_triad, &f32_triad) {
        let bw64 = b64 / s64.median;
        let bw32 = b32 / s32.median;
        let elems64 = bw64 / (3.0 * 8.0);
        let elems32 = bw32 / (3.0 * 4.0);
        section("D1 — f32 vs f64 triad");
        println!("bytes/sec    ratio f32/f64 = {:.2}", bw32 / bw64);
        println!("elements/sec ratio f32/f64 = {:.2} (ideal ≈ 2.0)", elems32 / elems64);
    }

    section("D1 — whole-benchmark serial runs (validated)");
    let nt = 3;
    if only.is_none() || only == Some(Dtype::F64) {
        let r64 = run_serial_t::<f64>(n.min(1 << 22), nt, STREAM_Q);
        assert!(r64.validation.passed, "{:?}", r64.validation);
        println!(
            "f64 triad {} (passes §III closed-form checks)",
            distarray::report::fmt_bw(r64.bandwidths()[3]),
        );
    }
    if only.is_none() || only == Some(Dtype::F32) {
        let r32 = run_serial_t::<f32>(n.min(1 << 22), nt, STREAM_Q as f32);
        assert!(r32.validation.passed, "{:?}", r32.validation);
        println!(
            "f32 triad {} (passes §III closed-form checks)",
            distarray::report::fmt_bw(r32.bandwidths()[3]),
        );
    }
    println!("\ndtype_stream done");
}
