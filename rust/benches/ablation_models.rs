//! Bench A3 — programming-model ablation (§II): the same STREAM
//! workload under distributed arrays, message passing, and
//! map-reduce. Distributed arrays should match map-reduce bandwidth
//! (both communication-free in steady state) while message passing
//! pays the explicit scatter/gather.

use distarray::baselines::{run_mapreduce_stream, run_msgpass_stream};
use distarray::benchx::section;
use distarray::comm::{ChannelHub, Transport};
use distarray::dmap::Dmap;
use distarray::stream::{aggregate, run_parallel, AggregateResult, STREAM_Q};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

fn run_distarray(np: usize, n: usize, nt: usize) -> (AggregateResult, u64) {
    let world = ChannelHub::world(np);
    let bytes = Arc::new(AtomicU64::new(0));
    let hs: Vec<_> = world
        .into_iter()
        .map(|t| {
            let bytes = bytes.clone();
            std::thread::spawn(move || {
                let r = run_parallel(&Dmap::block_1d(t.np()), n, nt, STREAM_Q, t.pid());
                bytes.fetch_add(t.stats().bytes_sent(), Ordering::Relaxed);
                r
            })
        })
        .collect();
    let rs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    (aggregate(&rs).unwrap(), bytes.load(Ordering::Relaxed))
}

fn run_model(
    np: usize,
    n: usize,
    nt: usize,
    f: fn(&dyn Transport, usize, usize, f64) -> distarray::comm::Result<distarray::stream::StreamResult>,
) -> (AggregateResult, u64) {
    let world = ChannelHub::world(np);
    let bytes = Arc::new(AtomicU64::new(0));
    let hs: Vec<_> = world
        .into_iter()
        .map(|t| {
            let bytes = bytes.clone();
            std::thread::spawn(move || {
                let r = f(&t, n, nt, STREAM_Q).unwrap();
                bytes.fetch_add(t.stats().bytes_sent(), Ordering::Relaxed);
                r
            })
        })
        .collect();
    let rs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    (aggregate(&rs).unwrap(), bytes.load(Ordering::Relaxed))
}

fn main() {
    let np = 4;
    let n = 1 << 21;
    let nt = 5;

    section("A3 — programming models on the same STREAM workload");
    let (da, da_bytes) = run_distarray(np, n, nt);
    let (mp, mp_bytes) = run_model(np, n, nt, run_msgpass_stream);
    let (mr, mr_bytes) = run_model(np, n, nt, run_mapreduce_stream);

    for (name, agg, bytes) in [
        ("distributed arrays", &da, da_bytes),
        ("message passing", &mp, mp_bytes),
        ("map-reduce", &mr, mr_bytes),
    ] {
        println!(
            "{name:<20} triad {:>12}  wire bytes {:>12}  valid={}",
            distarray::report::fmt_bw(agg.triad_bw()),
            bytes,
            agg.all_valid
        );
        assert!(agg.all_valid, "{name} failed validation");
    }

    // The paper's qualitative claims:
    assert_eq!(da_bytes, 0, "distributed arrays: zero communication");
    assert!(mr_bytes < 10_000, "map-reduce: control traffic only");
    assert!(
        mp_bytes as usize > n * 8,
        "message passing: pays explicit data distribution"
    );
    // Steady-state bandwidth comparable across models (loose band:
    // thread scheduling noise dominates at this scale — the models
    // differ in *communication*, not kernel throughput).
    let lo = da.triad_bw().min(mp.triad_bw()).min(mr.triad_bw());
    let hi = da.triad_bw().max(mp.triad_bw()).max(mr.triad_bw());
    let spread = hi / lo;
    println!("steady-state triad spread across models: {spread:.2}x");
    assert!(spread < 10.0, "kernel bandwidth should be model-independent");
    println!("\nablation_models OK — zero-comm distarray, control-only map-reduce, data-heavy msgpass");
}
