//! Bench A1 — interpreter-model ablation: the §VI Octave effect.
//! "The Octave interpreter defers the first copy ... and folds it into
//! triad, which is why the Octave results are generally ~30% lower."

use distarray::benchx::section;
use distarray::hardware::{simulate_stream, Era, Lang, NodeModel};
use distarray::stream::StreamParams;

fn main() {
    section("A1 — interpreter ablation (simulated xeon-g6, Np=1)");
    let era = Era::by_label("xeon-g6").unwrap();
    let node = NodeModel::new(era, 1, 1);
    let p = StreamParams { nt: 10, log2_local: 24 };

    let mut triad = std::collections::BTreeMap::new();
    for lang in Lang::ALL {
        let r = simulate_stream(&node, &p, lang);
        let bw = r.bandwidths();
        println!(
            "{:<8} copy={:>12} scale={:>12} add={:>12} triad={:>12}",
            lang.name(),
            distarray::report::fmt_bw(bw[0]),
            distarray::report::fmt_bw(bw[1]),
            distarray::report::fmt_bw(bw[2]),
            distarray::report::fmt_bw(bw[3]),
        );
        triad.insert(lang.name(), bw[3]);
    }

    let ratio = triad["octave"] / triad["matlab"];
    assert!((ratio - 0.7).abs() < 0.02, "octave/matlab triad ratio {ratio}");
    // ... while Octave's *copy* shows artificially high bandwidth (the
    // deferred copy-on-write makes the timed C=A nearly free).
    let copy_m = simulate_stream(&node, &p, Lang::Matlab).bandwidths()[0];
    let copy_o = simulate_stream(&node, &p, Lang::Octave).bandwidths()[0];
    assert!(copy_o > copy_m * 5.0, "deferred copy should look 'free'");
    println!("\noctave/matlab triad = {ratio:.3} (paper: ~0.70)");
    println!("ablation_interp OK");
}
