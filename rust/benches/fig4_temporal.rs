//! Bench F4 — regenerate Figure 4 (temporal scaling) and assert the
//! paper's headline ratios: ~10× core / ~100× node over 20 years,
//! ~5× GPU node over ~5 years.

use distarray::benchx::{bench, section};
use distarray::report::fig4;

fn main() {
    section("FIGURE 4 — temporal scaling");
    print!("{}", fig4::render());

    let (core, node, gpu) = fig4::headline_ratios();
    assert!((5.0..20.0).contains(&core), "core ratio {core}");
    assert!((50.0..200.0).contains(&node), "node ratio {node}");
    assert!((3.0..8.0).contains(&gpu), "gpu ratio {gpu}");

    let stats = bench(2, 50, fig4::points);
    println!("points regen: median {:.2} ms", stats.median * 1e3);
    println!("\nfig4_temporal OK — ratios within the paper's bands");
}
