//! Bench T1 — regenerate Table I and verify the era database drives
//! the simulated engine at the calibrated envelopes.

use distarray::benchx::{bench, section};
use distarray::hardware::{simulate_stream, Lang, NodeModel, ERAS};
use distarray::report::table1;
use distarray::stream::StreamParams;

fn main() {
    section("TABLE I — hardware specifications (regenerated)");
    print!("{}", table1::render());

    section("era model: single-core simulated triad vs calibration");
    for era in ERAS {
        let node = NodeModel::new(era, 1, 1);
        let p = StreamParams { nt: era.base_nt, log2_local: era.base_log2.min(24) };
        let stats = bench(2, 20, || simulate_stream(&node, &p, Lang::Matlab).triad_bw());
        let bw = simulate_stream(&node, &p, Lang::Matlab).triad_bw();
        println!(
            "{:<10} year={} sim core triad = {:>12}  (calib {:>12})  [model eval {:.1} µs]",
            era.label,
            era.year,
            distarray::report::fmt_bw(bw),
            distarray::report::fmt_bw(era.core_bw),
            stats.median * 1e6
        );
        assert!((bw - era.core_bw).abs() / era.core_bw < 0.05, "{}", era.label);
    }
    println!("\ntable1_eras OK");
}
