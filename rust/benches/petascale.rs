//! Bench H1 — the >1 PB/s headline: horizontal scaling sweep over a
//! SuperCloud-like CPU+GPU node mix.

use distarray::benchx::{bench, section};
use distarray::report::petascale;

fn main() {
    section("HEADLINE — horizontal scaling to >1 PB/s");
    print!("{}", petascale::render(1024));

    let n = petascale::nodes_to_reach(1e15, 4096).expect("PB/s reachable");
    assert!(
        (100..=1024).contains(&n),
        "PB/s should land at 'hundreds' of nodes, got {n}"
    );

    // Linearity check: doubling nodes doubles bandwidth.
    let pts = petascale::sweep(512);
    for w in pts.windows(2) {
        let r = w[1].bw / w[0].bw;
        assert!((1.9..2.1).contains(&r), "nonlinear step {r}");
    }

    let stats = bench(2, 50, || petascale::sweep(1024));
    println!("sweep regen: median {:.2} ms", stats.median * 1e3);
    println!("\npetascale OK — >1 PB/s at {n} nodes (paper: \"hundreds\")");
}
