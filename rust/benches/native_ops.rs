//! Bench P1 — native kernel roofline: per-op bandwidth of the L3 hot
//! path vs a `memcpy` roofline on this machine. The §Perf target is
//! triad ≥ 0.8× of the copy roofline (STREAM triad moves 24B/elem vs
//! copy's 16B/elem, so equal *bandwidth* is the roofline).

use distarray::benchx::{bench, report, section};
use distarray::stream::{ops, run_native_serial, STREAM_Q};
use std::hint::black_box;

fn main() {
    let n = 1 << 24; // 128 MiB per vector — out of L3 cache
    let a = vec![1.0f64; n];
    let b = vec![2.0f64; n];
    let mut c = vec![0.0f64; n];
    let mut d = vec![0.0f64; n];

    section("P1 — per-op native bandwidth (n = 2^24, out-of-cache)");
    let bytes_rw2 = 16.0 * n as f64; // copy, scale: 1R + 1W
    let bytes_rw3 = 24.0 * n as f64; // add, triad: 2R + 1W

    // black_box the destination REFERENCE so LLVM cannot prove the
    // stores unobserved and delete the loops (criterion's pattern).
    let s = bench(2, 9, || black_box(&mut c[..]).copy_from_slice(black_box(&a)));
    report("memcpy roofline (copy_from_slice)", &s, Some(bytes_rw2));
    let roofline = bytes_rw2 / s.median;

    let s_copy = bench(2, 9, || ops::copy(black_box(&mut c[..]), black_box(&a)));
    report("stream copy", &s_copy, Some(bytes_rw2));
    let s_scale = bench(2, 9, || ops::scale(black_box(&mut c[..]), black_box(&a), STREAM_Q));
    report("stream scale", &s_scale, Some(bytes_rw2));
    let s_add = bench(2, 9, || ops::add(black_box(&mut d[..]), black_box(&a), black_box(&b)));
    report("stream add", &s_add, Some(bytes_rw3));
    let s_triad = bench(2, 9, || {
        ops::triad(black_box(&mut d[..]), black_box(&b), black_box(&c), STREAM_Q)
    });
    report("stream triad", &s_triad, Some(bytes_rw3));

    let triad_bw = bytes_rw3 / s_triad.median;
    println!(
        "\ntriad/roofline = {:.2} (target ≥ 0.8)",
        triad_bw / roofline
    );

    section("P1 — whole-benchmark serial run");
    let r = run_native_serial(n, 3, STREAM_Q);
    assert!(r.validation.passed);
    let bw = r.bandwidths();
    println!(
        "serial n=2^24 nt=3: copy={} scale={} add={} triad={}",
        distarray::report::fmt_bw(bw[0]),
        distarray::report::fmt_bw(bw[1]),
        distarray::report::fmt_bw(bw[2]),
        distarray::report::fmt_bw(bw[3]),
    );
    println!("\nnative_ops done (roofline ratio recorded in EXPERIMENTS.md §Perf)");
}
