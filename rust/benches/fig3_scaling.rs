//! Bench F3 — regenerate Figure 3: triad bandwidth vs Np for every
//! era × language (simulated engine) plus a measured vertical-scaling
//! series on this machine (native engine).
//!
//! Shape checks (not absolute numbers): vertical scaling rises then
//! saturates; Octave sits ~30% below Matlab; horizontal scaling is
//! linear.

use distarray::benchx::{bench, section};
use distarray::hardware::{Era, Lang};
use distarray::report::fig3;

fn main() {
    section("FIGURE 3 — simulated panels (8 eras × 3 languages)");
    let all = fig3::simulate_all();
    print!("{}", fig3::render(&all));

    section("shape checks");
    for era_label in ["amd-e9", "xeon-p8", "xeon-g6", "xeon-e5"] {
        let era = Era::by_label(era_label).unwrap();
        let m = fig3::simulate_series(era, Lang::Matlab);
        let first = m.points.first().unwrap().triad_bw;
        let last = m.points.last().unwrap().triad_bw;
        assert!(last > first * 4.0, "{era_label}: vertical scaling too flat");
        let o = fig3::simulate_series(era, Lang::Octave);
        let ratio = o.points.last().unwrap().triad_bw / last;
        assert!((ratio - 0.7).abs() < 0.05, "{era_label}: octave ratio {ratio}");
        println!("{era_label:<10} rise {:.1}x, octave/matlab {ratio:.2}", last / first);
    }

    section("measured vertical scaling on this machine (native engine)");
    let max_np = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let stats = bench(0, 3, || fig3::measured_series(max_np, 1 << 21, 3));
    let series = fig3::measured_series(max_np, 1 << 21, 3);
    for p in &series.points {
        println!(
            "  Np={:<3} triad {:>12}",
            p.np,
            distarray::report::fmt_bw(p.triad_bw)
        );
    }
    println!("  (series regen median {:.1} ms)", stats.median * 1e3);
    println!("\nfig3_scaling OK");
}
