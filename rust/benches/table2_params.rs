//! Bench T2 — regenerate Table II (the Nt, N/Np schedule) and check
//! the published anchor cells.

use distarray::benchx::{bench, section};
use distarray::report::table2;

fn main() {
    section("TABLE II — single-node STREAM parameters (regenerated)");
    print!("{}", table2::render());

    section("schedule derivation cost");
    let stats = bench(5, 100, table2::rows);
    println!("derive all rows: median {:.1} µs", stats.median * 1e6);

    // Anchor cells from the paper.
    let rows = table2::rows();
    let cell = |era: &str, np: usize| {
        let r = rows.iter().find(|r| r.era.label == era).unwrap();
        r.cells.iter().find(|(c, _)| *c == np).map(|(_, p)| (p.nt, p.log2_local)).unwrap()
    };
    assert_eq!(cell("xeon-p8", 8), (20, 29), "xeon-p8 Np=8 → 20, 2^29");
    assert_eq!(cell("xeon-p8", 32), (80, 27), "xeon-p8 Np=32 → 80, 2^27");
    assert_eq!(cell("amd-e9", 1), (20, 30), "amd-e9 Np=1 → 20, 2^30");
    assert_eq!(cell("bg-p", 128), (10, 25), "bg-p Np=128 → 10, 2^25");
    assert_eq!(cell("xeon-p4", 1), (10, 25), "xeon-p4 Np=1 → 10, 2^25");
    println!("\ntable2_params OK — anchor cells match the paper");
}
