"""L1 §Perf instrument — block-shape and fusion ablation.

interpret=True gives CPU-numpy timings only (NOT a TPU proxy), so the
optimization signal here is *structural*: HLO size, kernel-launch
count, VMEM footprint per grid step — plus CPU wallclock as a sanity
check that fusion reduces traffic.

Usage (from python/):  python -m compile.perf_blocks [--n 1048576]

Output is pasted into EXPERIMENTS.md §Perf (L1).
"""

from __future__ import annotations

import argparse
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .kernels import stream_kernels as k
from .kernels import ref, tiled


def hlo_ops(fn, *args) -> int:
    """Number of HLO instructions in the optimized lowering."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    return sum(1 for line in text.splitlines() if "=" in line)


def timeit(fn, *args, reps=3) -> float:
    fn(*args)[0].block_until_ready()  # warm
    best = float("inf")
    for _ in range(reps):
        t = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        best = min(best, time.perf_counter() - t)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 20)
    args = ap.parse_args()
    n = args.n
    a = jnp.ones((n,), jnp.float64)
    q = jnp.float64(ref.STREAM_Q)

    print(f"L1 perf ablation, n={n} (f64)")
    print("\n-- fusion: 4 discrete kernels vs 1 fused kernel --")

    def discrete(a, q):
        c = k.copy(a)
        b = k.scale(c, q)
        c = k.add(a, b)
        return (k.triad(b, c, q),)

    def fused(a, q):
        return k.fused_step(a, q)

    t_d = timeit(discrete, a, q)
    t_f = timeit(fused, a, q)
    print(f"discrete 4-op step : {t_d * 1e3:8.2f} ms   ({hlo_ops(discrete, a, q)} HLO ops)")
    print(f"fused 1-op step    : {t_f * 1e3:8.2f} ms   ({hlo_ops(fused, a, q)} HLO ops)")
    print(f"fusion speedup     : {t_d / t_f:8.2f}x  (HBM round-trips 8 -> 2 per element)")

    print("\n-- block sweep (fused 1-D kernel) --")
    for blk in [1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]:
        t = timeit(lambda a, q, blk=blk: k.fused_step(a, q, block=blk), a, q)
        vmem = 4 * blk * 8
        print(f"block {blk:>8} : {t * 1e3:8.2f} ms   VMEM/step {vmem / 2**20:6.2f} MiB")

    print("\n-- lane-tiled (rows x 128) row_block sweep --")
    for rb in [64, 256, 512, 2048]:
        t = timeit(lambda a, q, rb=rb: tiled.fused_step_tiled(a, q, row_block=rb), a, q)
        print(
            f"row_block {rb:>5} : {t * 1e3:8.2f} ms   VMEM/step "
            f"{tiled.vmem_bytes(rb) / 2**20:6.2f} MiB"
        )


if __name__ == "__main__":
    main()
