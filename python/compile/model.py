"""L2 — the paper's compute graph in JAX, calling the L1 Pallas kernels.

The "model" for this paper is the STREAM benchmark itself (§III,
Algorithms 1 & 2): three N-element f64 vectors and the four ops
Copy / Scale / Add / Triad, repeated Nt times, plus the closed-form
validator.  Each public function here is jitted and AOT-lowered by
``aot.py`` to an HLO text artifact the Rust runtime loads.

Distributed-array note: under the paper's same-map design (Figure 2)
each PID runs these functions on its *local* part only — so the shapes
lowered here are the per-PID local lengths (N/Np), and the Rust L3
coordinator owns the map/PID logic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref, stream_kernels as k

DTYPE = jnp.float64


def stream_copy(a):
    """C = A (L1 kernel)."""
    return k.copy(a)


def stream_scale(c, q):
    """B = q*C (L1 kernel)."""
    return k.scale(c, q)


def stream_add(a, b):
    """C = A+B (L1 kernel)."""
    return k.add(a, b)


def stream_triad(b, c, q):
    """A = B+q*C (L1 kernel)."""
    return k.triad(b, c, q)


def stream_step(a, b, c, q):
    """One STREAM iteration as four discrete kernel launches.

    Faithful to Algorithm 1/2's op-by-op structure (each op separately
    timed in the paper); used by the per-op PJRT artifacts.
    """
    c = k.copy(a)
    b = k.scale(c, q)
    c = k.add(a, b)
    a = k.triad(b, c, q)
    return a, b, c


def stream_step_fused(a, q):
    """One STREAM iteration as a single fused L1 kernel (perf variant).

    B and C are fully determined by A within an iteration, so only A
    flows in. Returns (A', B', C').
    """
    return k.fused_step(a, q)


def stream_run(a, b, c, q, nt: int):
    """Nt STREAM iterations via lax.scan over the fused step.

    ``scan`` (not a Python loop) keeps the lowered HLO size O(1) in Nt.
    Within an iteration B and C are fully determined by the incoming A,
    so the scan carry is A alone; the last iteration runs outside the
    scan so the final (A, B, C) triple matches Algorithm 1 exactly
    (B and C as left by iteration Nt). Requires nt >= 1.
    """

    def body(a, _):
        a2, _, _ = k.fused_step(a, q)
        return a2, None

    a_prev, _ = jax.lax.scan(body, a, None, length=nt - 1)
    return k.fused_step(a_prev, q)


def stream_validate(a, b, c, q, nt: int):
    """Max absolute validation error against the §III closed forms.

    Returns a length-3 vector [errA, errB, errC]; the Rust coordinator
    asserts each < 1e-8 * nt.
    """
    g = 2.0 * q + q * q
    a_prev = g ** (nt - 1)
    err_a = jnp.max(jnp.abs(a - g**nt))
    err_b = jnp.max(jnp.abs(b - q * a_prev))
    err_c = jnp.max(jnp.abs(c - (1.0 + q) * a_prev))
    return jnp.stack([err_a, err_b, err_c])


def reference_run(a, b, c, q, nt: int):
    """Pure-jnp reference of stream_run (for L2-vs-ref pytest)."""
    return ref.run(a, b, c, q, nt)
