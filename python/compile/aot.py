"""AOT compile path: lower the L2 JAX functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
which the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
``HloModuleProto::from_text_file`` on the Rust side reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --out ../artifacts/model.hlo.txt [--n 65536] [--nt 10]

Emits one ``.hlo.txt`` per L2 entry point plus ``manifest.json`` so the
Rust runtime knows each artifact's shapes without re-parsing HLO.

Python runs ONLY here — never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # STREAM mandates f64 (§III)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _vec(n):
    return jax.ShapeDtypeStruct((n,), jnp.float64)


def _scalar():
    return jax.ShapeDtypeStruct((), jnp.float64)


def build_artifacts(n: int, nt: int):
    """Return {name: (lowered, meta)} for every artifact."""
    v, s = _vec(n), _scalar()
    arts = {}

    def low(name, fn, *specs, donate=(), meta=None):
        jitted = jax.jit(fn, donate_argnums=donate)
        arts[name] = (jitted.lower(*specs), meta or {})

    # Per-op artifacts — Algorithm 1's individually-timed operations.
    low("copy", model.stream_copy, v, meta={"inputs": [["f64", n]], "outputs": 1})
    low("scale", model.stream_scale, v, s, meta={"inputs": [["f64", n], ["f64"]], "outputs": 1})
    low("add", model.stream_add, v, v, meta={"inputs": [["f64", n], ["f64", n]], "outputs": 1})
    low("triad", model.stream_triad, v, v, s, meta={"inputs": [["f64", n], ["f64", n], ["f64"]], "outputs": 1})
    # Fused single iteration (perf variant) and the full Nt-run.
    low("step_fused", model.stream_step_fused, v, s, meta={"inputs": [["f64", n], ["f64"]], "outputs": 3})
    # NOTE: the run entry point takes (a, q) only — within the STREAM
    # recurrence B and C are fully determined by A, and jax.jit prunes
    # unused parameters from the lowered module anyway.
    low(
        "run",
        lambda a, q: model.stream_run(a, a, a, q, nt),
        v, s,
        meta={"inputs": [["f64", n], ["f64"]], "outputs": 3, "nt": nt},
    )
    low(
        "validate",
        lambda a, b, c, q: model.stream_validate(a, b, c, q, nt),
        v, v, v, s,
        meta={"inputs": [["f64", n]] * 3 + [["f64"]], "outputs": 1, "nt": nt},
    )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the primary artifact (its dir receives all artifacts)")
    ap.add_argument("--n", type=int, default=65536,
                    help="local vector length lowered into the artifacts")
    ap.add_argument("--nt", type=int, default=10, help="iterations baked into the `run` artifact")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"n": args.n, "nt": args.nt, "dtype": "f64", "artifacts": {}}
    for name, (lowered, meta) in build_artifacts(args.n, args.nt).items():
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": f"{name}.hlo.txt", **meta}
        print(f"  wrote {path} ({len(text)} chars)")

    # The Makefile's stamp target: model.hlo.txt = the fused step artifact.
    import shutil

    shutil.copyfile(os.path.join(out_dir, "step_fused.hlo.txt"), os.path.abspath(args.out))
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
