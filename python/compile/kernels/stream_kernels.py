"""L1 — Pallas kernels for the STREAM benchmark operations.

The paper's compute hot-spot is the four STREAM vector operations
(Copy, Scale, Add, Triad; §III Algorithm 1).  Each kernel is expressed
as a Pallas kernel tiled with a ``BlockSpec`` so that every grid step
streams one VMEM-resident tile — this is the explicit HBM↔VMEM schedule
that the paper's CuPy/gpuArray path left implicit (DESIGN.md
§Hardware-Adaptation).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret-mode lowers to plain
HLO which the Rust runtime loads via ``HloModuleProto::from_text_file``.

VMEM budget: the fused step touches three tiles (A, B, C) of
``block_size`` f64 elements → ``3 * block * 8`` bytes per grid step.
The default ``block=65536`` gives 1.5 MiB, well under ~16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536


def _grid_for(n: int, block: int) -> tuple[int, int]:
    """Clamp block to n and return (block, grid)."""
    block = min(block, n)
    if n % block != 0:
        # Fall back to a divisor block so BlockSpec tiles exactly.
        block = _largest_divisor_block(n, block)
    return block, n // block


def _largest_divisor_block(n: int, block: int) -> int:
    for b in range(min(block, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _scale_kernel(q_ref, c_ref, b_ref):
    b_ref[...] = q_ref[0] * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(q_ref, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + q_ref[0] * c_ref[...]


def _block_spec(block: int):
    return pl.BlockSpec((block,), lambda i: (i,))


def _scalar_spec():
    # The scalar q is broadcast to every grid step.
    return pl.BlockSpec((1,), lambda i: (0,))


def copy(a: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM Copy: C = A."""
    (n,) = a.shape
    block, grid = _grid_for(n, block)
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        grid=(grid,),
        in_specs=[_block_spec(block)],
        out_specs=_block_spec(block),
        interpret=True,
    )(a)


def scale(c: jax.Array, q: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM Scale: B = q * C."""
    (n,) = c.shape
    block, grid = _grid_for(n, block)
    q1 = jnp.reshape(q.astype(c.dtype), (1,))
    return pl.pallas_call(
        _scale_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        grid=(grid,),
        in_specs=[_scalar_spec(), _block_spec(block)],
        out_specs=_block_spec(block),
        interpret=True,
    )(q1, c)


def add(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM Add: C = A + B."""
    (n,) = a.shape
    block, grid = _grid_for(n, block)
    return pl.pallas_call(
        _add_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        grid=(grid,),
        in_specs=[_block_spec(block), _block_spec(block)],
        out_specs=_block_spec(block),
        interpret=True,
    )(a, b)


def triad(b: jax.Array, c: jax.Array, q: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """STREAM Triad: A = B + q * C."""
    (n,) = b.shape
    block, grid = _grid_for(n, block)
    q1 = jnp.reshape(q.astype(b.dtype), (1,))
    return pl.pallas_call(
        _triad_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        grid=(grid,),
        in_specs=[_scalar_spec(), _block_spec(block), _block_spec(block)],
        out_specs=_block_spec(block),
        interpret=True,
    )(q1, b, c)


def _fused_step_kernel(q_ref, a_ref, ao_ref, bo_ref, co_ref):
    """One full STREAM iteration fused into a single tile pass.

    Within one iteration the dataflow collapses onto A:
        C = A;  B = qC = qA;  C = A + B = (1+q)A;  A' = B + qC = (2q+q^2)A
    Fusing removes three of the four HBM round-trips per iteration —
    the L1 perf optimization recorded in EXPERIMENTS.md §Perf.
    """
    q = q_ref[0]
    a = a_ref[...]
    c = a  # Copy
    b = q * c  # Scale
    c = a + b  # Add
    ao_ref[...] = b + q * c  # Triad
    bo_ref[...] = b
    co_ref[...] = c


def fused_step(a: jax.Array, q: jax.Array, *, block: int = DEFAULT_BLOCK):
    """One STREAM iteration (Copy, Scale, Add, Triad) as a single kernel.

    Returns (A', B', C') after the iteration.
    """
    (n,) = a.shape
    block, grid = _grid_for(n, block)
    q1 = jnp.reshape(q.astype(a.dtype), (1,))
    out = jax.ShapeDtypeStruct((n,), a.dtype)
    return pl.pallas_call(
        _fused_step_kernel,
        out_shape=(out, out, out),
        grid=(grid,),
        in_specs=[_scalar_spec(), _block_spec(block)],
        out_specs=(_block_spec(block), _block_spec(block), _block_spec(block)),
        interpret=True,
    )(q1, a)
