"""Pure-jnp oracle for the STREAM kernels (§III of the paper).

Every Pallas kernel in ``stream_kernels.py`` is checked against these
reference implementations at build time (pytest) — the CORE correctness
signal for L1.
"""

from __future__ import annotations

import jax.numpy as jnp


def copy(a):
    """C = A."""
    return jnp.asarray(a)


def scale(c, q):
    """B = q * C."""
    return q * c


def add(a, b):
    """C = A + B."""
    return a + b


def triad(b, c, q):
    """A = B + q * C."""
    return b + q * c


def step(a, b, c, q):
    """One full STREAM iteration: Copy, Scale, Add, Triad (in order)."""
    c = copy(a)
    b = scale(c, q)
    c = add(a, b)
    a = triad(b, c, q)
    return a, b, c


def run(a, b, c, q, nt: int):
    """Run ``nt`` STREAM iterations."""
    for _ in range(nt):
        a, b, c = step(a, b, c, q)
    return a, b, c


def validate_closed_form(a0: float, q: float, nt: int):
    """Closed-form final values (§III validation formulas).

    A_{Nt}(:) = (2q + q^2)^{Nt} * A0
    B_{Nt}(:) = q * A_{Nt-1}
    C_{Nt}(:) = (1+q) * A_{Nt-1}
    where A_{Nt-1} = (2q + q^2)^{Nt-1} * A0.
    """
    g = 2.0 * q + q * q
    a_prev = g ** (nt - 1) * a0
    a_final = g**nt * a0
    b_final = q * a_prev
    c_final = (1.0 + q) * a_prev
    return a_final, b_final, c_final


STREAM_Q = float(jnp.sqrt(2.0) - 1.0)  # 2q + q^2 == 1 → values stay modest
