"""TPU lane-tiled STREAM kernels — the DESIGN.md §Hardware-Adaptation
variant.

The paper's GPU path (CuPy/gpuArray) leaves the HBM schedule implicit.
On TPU the natural layout for the VPU is (sublane, lane) = (8, 128)
tiles; these kernels reshape the 1-D STREAM vectors to ``(rows, 128)``
and tile with a 2-D ``BlockSpec`` so each grid step streams
``row_block × 128`` elements through VMEM — the explicit HBM↔VMEM
schedule.

VMEM per grid step (fused): 3 tiles x row_block x 128 x 8 B.
Default ``row_block=512`` → 1.5 MiB, well under ~16 MiB VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_ROW_BLOCK = 512


def _shape2d(n: int) -> tuple[int, int]:
    assert n % LANES == 0, f"tiled kernels need n % {LANES} == 0, got {n}"
    return n // LANES, LANES


def _grid(rows: int, row_block: int) -> tuple[int, int]:
    rb = min(row_block, rows)
    while rows % rb != 0:
        rb -= 1
    return rb, rows // rb


def _spec(rb: int):
    return pl.BlockSpec((rb, LANES), lambda i: (i, 0))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda i: (0,))


def _fused_kernel(q_ref, a_ref, ao_ref, bo_ref, co_ref):
    q = q_ref[0]
    a = a_ref[...]
    c = a  # Copy
    b = q * c  # Scale
    c = a + b  # Add
    ao_ref[...] = b + q * c  # Triad
    bo_ref[...] = b
    co_ref[...] = c


def fused_step_tiled(a: jax.Array, q: jax.Array, *, row_block: int = DEFAULT_ROW_BLOCK):
    """One STREAM iteration over lane-tiled (rows, 128) layout.

    Accepts and returns 1-D arrays; the 2-D tiling is internal.
    """
    (n,) = a.shape
    rows, _ = _shape2d(n)
    rb, grid = _grid(rows, row_block)
    a2 = a.reshape(rows, LANES)
    q1 = jnp.reshape(q.astype(a.dtype), (1,))
    out = jax.ShapeDtypeStruct((rows, LANES), a.dtype)
    ao, bo, co = pl.pallas_call(
        _fused_kernel,
        out_shape=(out, out, out),
        grid=(grid,),
        in_specs=[_scalar_spec(), _spec(rb)],
        out_specs=(_spec(rb), _spec(rb), _spec(rb)),
        interpret=True,
    )(q1, a2)
    return ao.reshape(n), bo.reshape(n), co.reshape(n)


def vmem_bytes(row_block: int, dtype_bytes: int = 8, buffers: int = 4) -> int:
    """VMEM footprint estimate per grid step: ``buffers`` resident
    tiles (A in + A' B' C' out for the fused kernel) of
    ``row_block × 128`` elements."""
    return buffers * row_block * LANES * dtype_bytes
