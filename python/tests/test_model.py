"""L2 pytest: model graph vs pure-jnp reference; AOT lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref

Q = ref.STREAM_Q


def _abc(n):
    a = jnp.full((n,), 1.0, dtype=jnp.float64)
    b = jnp.full((n,), 2.0, dtype=jnp.float64)
    c = jnp.zeros((n,), dtype=jnp.float64)
    return a, b, c


@pytest.mark.parametrize("n,nt", [(64, 1), (64, 3), (1024, 10), (4096, 5)])
def test_stream_run_matches_ref(n, nt):
    a, b, c = _abc(n)
    q = jnp.float64(Q)
    got = model.stream_run(a, b, c, q, nt)
    want = ref.run(a, b, c, Q, nt)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)


@pytest.mark.parametrize("nt", [1, 2, 10, 50])
def test_stream_run_closed_form(nt):
    n = 256
    a, b, c = _abc(n)
    fa, fb, fc = ref.validate_closed_form(1.0, Q, nt)
    ga, gb, gc = model.stream_run(a, b, c, jnp.float64(Q), nt)
    assert_allclose(np.asarray(ga), fa, rtol=1e-11)
    assert_allclose(np.asarray(gb), fb, rtol=1e-11)
    assert_allclose(np.asarray(gc), fc, rtol=1e-11)


def test_validate_zero_on_correct_run():
    n, nt = 512, 10
    a, b, c = _abc(n)
    q = jnp.float64(Q)
    a2, b2, c2 = model.stream_run(a, b, c, q, nt)
    errs = model.stream_validate(a2, b2, c2, q, nt)
    assert np.all(np.asarray(errs) < 1e-10)


def test_validate_detects_corruption():
    n, nt = 512, 4
    a, b, c = _abc(n)
    q = jnp.float64(Q)
    a2, b2, c2 = model.stream_run(a, b, c, q, nt)
    a_bad = a2.at[17].set(a2[17] + 1.0)
    errs = model.stream_validate(a_bad, b2, c2, q, nt)
    assert np.asarray(errs)[0] > 0.5


def test_step_fused_equals_discrete_step():
    n = 2048
    a, b, c = _abc(n)
    q = jnp.float64(Q)
    fa, fb, fc = model.stream_step_fused(a, q)
    da, db, dc = model.stream_step(a, b, c, q)
    assert_allclose(np.asarray(fa), np.asarray(da), rtol=1e-14)
    assert_allclose(np.asarray(fb), np.asarray(db), rtol=1e-14)
    assert_allclose(np.asarray(fc), np.asarray(dc), rtol=1e-14)


# ---------- AOT lowering ----------


def test_all_artifacts_lower_to_hlo_text():
    arts = aot.build_artifacts(n=256, nt=3)
    assert set(arts) == {"copy", "scale", "add", "triad", "step_fused", "run", "validate"}
    for name, (lowered, meta) in arts.items():
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "f64" in text, name


def test_artifact_files_roundtrip(tmp_path):
    import json
    import sys

    out = tmp_path / "model.hlo.txt"
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out), "--n", "128", "--nt", "2"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["n"] == 128 and manifest["nt"] == 2
    for name, entry in manifest["artifacts"].items():
        text = (tmp_path / entry["file"]).read_text()
        assert text.startswith("HloModule"), name
    assert out.read_text().startswith("HloModule")
