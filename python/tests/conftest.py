import sys
from pathlib import Path

# Make the `compile` package importable regardless of the pytest
# invocation directory (repo root, python/, or python/tests).
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

jax.config.update("jax_enable_x64", True)  # STREAM mandates f64 (§III)
