"""Lane-tiled kernel (hardware-adaptation variant) vs the oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis unavailable offline")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, tiled

Q = ref.STREAM_Q


@pytest.mark.parametrize("n", [128, 1024, 128 * 7, 65536])
def test_tiled_fused_matches_ref(n):
    a = jnp.asarray(np.random.default_rng(1).standard_normal(n))
    ta, tb, tc = tiled.fused_step_tiled(a, jnp.float64(Q))
    ra, rb, rc = ref.step(a, a, a, Q)
    assert_allclose(np.asarray(ta), np.asarray(ra), rtol=1e-13, atol=1e-13)
    assert_allclose(np.asarray(tb), np.asarray(rb), rtol=1e-13, atol=1e-13)
    assert_allclose(np.asarray(tc), np.asarray(rc), rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("row_block", [1, 8, 100, 512, 4096])
def test_row_blocks_equivalent(row_block):
    n = 128 * 32
    a = jnp.asarray(np.random.default_rng(2).standard_normal(n))
    got = tiled.fused_step_tiled(a, jnp.float64(Q), row_block=row_block)
    want = ref.step(a, a, a, Q)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-13, atol=1e-13)


def test_non_multiple_of_128_rejected():
    a = jnp.ones(127)
    with pytest.raises(AssertionError):
        tiled.fused_step_tiled(a, jnp.float64(Q))


def test_vmem_budget_under_16mib():
    # The default tiling must fit comfortably in ~16 MiB VMEM.
    assert tiled.vmem_bytes(tiled.DEFAULT_ROW_BLOCK) < 16 * 2**20 / 4


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=64),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_hypothesis_tiled_shapes_dtypes(rows, dtype):
    n = rows * 128
    a = jnp.asarray(np.random.default_rng(rows).standard_normal(n).astype(dtype))
    q = jnp.asarray(Q, dtype=dtype)
    got = tiled.fused_step_tiled(a, q)
    want = ref.step(a, a, a, q)
    tol = 1e-5 if dtype == np.float32 else 1e-12
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=tol, atol=tol)
