"""L1 pytest: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes per the session guide; assert_allclose
against ref is the CORE correctness signal.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis unavailable offline")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref, stream_kernels as k

RNG = np.random.default_rng(0)
Q = ref.STREAM_Q


def _vec(n, dtype=np.float64, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(n).astype(dtype))


# ---------- fixed-shape smoke ----------


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 65536, 65536 + 13])
def test_copy_matches_ref(n):
    a = _vec(n)
    assert_allclose(np.asarray(k.copy(a)), np.asarray(ref.copy(a)), rtol=0, atol=0)


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 65536 + 13])
def test_scale_matches_ref(n):
    c = _vec(n, seed=1)
    q = jnp.float64(Q)
    assert_allclose(np.asarray(k.scale(c, q)), np.asarray(ref.scale(c, q)), rtol=1e-15)


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 65536 + 13])
def test_add_matches_ref(n):
    a, b = _vec(n, seed=2), _vec(n, seed=3)
    assert_allclose(np.asarray(k.add(a, b)), np.asarray(ref.add(a, b)), rtol=1e-15)


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 65536 + 13])
def test_triad_matches_ref(n):
    b, c = _vec(n, seed=4), _vec(n, seed=5)
    q = jnp.float64(Q)
    # rtol loose enough for FMA-contraction differences between paths.
    assert_allclose(np.asarray(k.triad(b, c, q)), np.asarray(ref.triad(b, c, q)), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n", [1, 128, 4096, 65536 + 13])
def test_fused_step_matches_ref(n):
    a = _vec(n, seed=6)
    b0, c0 = _vec(n, seed=7), _vec(n, seed=8)
    a2, b2, c2 = k.fused_step(a, jnp.float64(Q))
    ra, rb, rc = ref.step(a, b0, c0, Q)
    assert_allclose(np.asarray(a2), np.asarray(ra), rtol=1e-14)
    assert_allclose(np.asarray(b2), np.asarray(rb), rtol=1e-14)
    assert_allclose(np.asarray(c2), np.asarray(rc), rtol=1e-14)


# ---------- block-shape sweep (the L1 tiling knob) ----------


@pytest.mark.parametrize("block", [1, 16, 1000, 65536, 1 << 20])
def test_block_sizes_equivalent(block):
    n = 4096
    a = _vec(n, seed=9)
    out = k.fused_step(a, jnp.float64(Q), block=block)
    rout = ref.step(a, a, a, Q)
    for got, want in zip(out, rout):
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-14)


# ---------- hypothesis: shapes × dtypes × q ----------


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=8192),
    dtype=st.sampled_from([np.float32, np.float64]),
    q=st.floats(min_value=-2.0, max_value=2.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_ops(n, dtype, q, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(n).astype(dtype))
    b = jnp.asarray(rng.standard_normal(n).astype(dtype))
    c = jnp.asarray(rng.standard_normal(n).astype(dtype))
    qj = jnp.asarray(q, dtype=dtype)
    tol = 1e-6 if dtype == np.float32 else 1e-13
    assert_allclose(np.asarray(k.copy(a)), np.asarray(ref.copy(a)), rtol=0, atol=0)
    assert_allclose(np.asarray(k.scale(c, qj)), np.asarray(ref.scale(c, qj)), rtol=tol, atol=tol)
    assert_allclose(np.asarray(k.add(a, b)), np.asarray(ref.add(a, b)), rtol=tol, atol=tol)
    assert_allclose(np.asarray(k.triad(b, c, qj)), np.asarray(ref.triad(b, c, qj)), rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=4096),
    nt=st.integers(min_value=1, max_value=8),
)
def test_hypothesis_iterated_matches_closed_form(n, nt):
    """Iterated fused kernel reproduces the §III closed forms with q=√2−1."""
    a = jnp.full((n,), 1.0, dtype=jnp.float64)
    b = jnp.full((n,), 2.0, dtype=jnp.float64)
    c = jnp.zeros((n,), dtype=jnp.float64)
    q = jnp.float64(Q)
    for _ in range(nt):
        a, b, c = k.fused_step(a, q)
    fa, fb, fc = ref.validate_closed_form(1.0, Q, nt)
    assert_allclose(np.asarray(a), fa, rtol=1e-12)
    assert_allclose(np.asarray(b), fb, rtol=1e-12)
    assert_allclose(np.asarray(c), fc, rtol=1e-12)


def test_grid_divisor_fallback():
    # n prime and > block → _grid_for must fall back to a divisor.
    block, grid = k._grid_for(65537, 65536)
    assert block * grid == 65537
    assert block >= 1
