//! Pipeline maps — §II: "pipelines can be implemented by mapping
//! different arrays to different sets of PIDs."
//!
//! A three-stage pipeline over an 8-PID world:
//!   stage 0 (PIDs 0-2): generate a signal
//!   stage 1 (PIDs 3-5): scale it (owner-computes on its subset)
//!   stage 2 (PIDs 6-7): reduce to a checksum
//! Data moves between stages with bounded, explicit transfers.
//!
//! ```text
//! cargo run --release --example pipeline
//! ```

use distarray::comm::{ChannelHub, Transport};
use distarray::darray::{stage_map, StageArray};
use distarray::dmap::Partition;
use std::thread;

fn main() {
    let np = 8;
    let n = 1 << 16;
    let world = ChannelHub::world(np);
    let handles: Vec<_> = world
        .into_iter()
        .map(|t| thread::spawn(move || run_pid(&t, n)))
        .collect();
    let sums: Vec<Option<f64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let checksums: Vec<f64> = sums.into_iter().flatten().collect();
    // Stage-2 members all computed the same checksum.
    assert_eq!(checksums.len(), 2);
    assert!((checksums[0] - checksums[1]).abs() < 1e-9);
    // signal g -> 2g scaled by 0.5 -> g; sum = n(n-1)/2
    let want = (n * (n - 1) / 2) as f64;
    assert!((checksums[0] - want).abs() < 1e-6, "{} vs {want}", checksums[0]);
    println!("pipeline OK — 3 stages over disjoint PID subsets, checksum {want}");
}

fn run_pid(t: &dyn Transport, n: usize) -> Option<f64> {
    let me = t.pid();
    let m0 = stage_map(&[0, 1, 2]);
    let m1 = stage_map(&[3, 4, 5]);
    let m2 = stage_map(&[6, 7]);

    // Stage 0: generate signal x[g] = 2g.
    let mut s0 = StageArray::zeros(m0, &[n], me);
    if let Some(arr) = &mut s0.local {
        let part = Partition::of(arr.map(), &[n]);
        let mut off = 0;
        for r in part.ranges_of(me) {
            for g in r.lo..r.hi {
                arr.loc_mut()[off] = (2 * g) as f64;
                off += 1;
            }
        }
    }

    // Stage 0 → 1.
    let mut s1 = StageArray::zeros(m1, &[n], me);
    s0.send_to(&mut s1, t, 0).unwrap();

    // Stage 1: scale by 0.5 (owner-computes, no communication).
    if let Some(arr) = &mut s1.local {
        for x in arr.loc_mut() {
            *x *= 0.5;
        }
    }

    // Stage 1 → 2.
    let mut s2 = StageArray::zeros(m2, &[n], me);
    s1.send_to(&mut s2, t, 1).unwrap();

    // Stage 2: checksum via gather of own pieces (local reduction +
    // exchange between the two stage members).
    if let Some(arr) = &s2.local {
        let local_sum: f64 = arr.loc().iter().sum();
        // two-member allreduce: swap partial sums directly
        let peer = if me == 6 { 7 } else { 6 };
        let mut w = distarray::comm::WireWriter::new();
        w.put_f64(local_sum);
        t.send(peer, 0xCAFE, &w.finish()).unwrap();
        let payload = t.recv(peer, 0xCAFE).unwrap();
        let other = distarray::comm::WireReader::new(&payload).get_f64().unwrap();
        Some(local_sum + other)
    } else {
        None
    }
}
