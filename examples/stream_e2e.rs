//! END-TO-END driver — exercises the full system on a real workload,
//! proving all layers compose (EXPERIMENTS.md records this run):
//!
//! 1. **L1→L2→L3**: load the AOT artifacts (Pallas kernels lowered
//!    through JAX to HLO) and validate their numerics against the
//!    closed forms from Rust via PJRT.
//! 2. **Coordinator**: leader/worker STREAM over the file-based
//!    messaging transport (the paper's aggregation path [44]), native
//!    engine, block map — Figure 2's zero-communication design.
//! 3. **Map independence**: the same run under a cyclic map.
//! 4. **Remap**: a deliberate block→cyclic global assignment, showing
//!    bounded communication.
//! 5. **Reports**: regenerate Table II and the Figure 4 ratios.
//!
//! ```text
//! make artifacts && cargo run --release --example stream_e2e
//! ```

use distarray::comm::{ChannelHub, Transport};
use distarray::coordinator::{run_leader, run_worker, EngineKind, MapKind, RunConfig};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use distarray::report::{fig4, fmt_bw};
use distarray::stream::STREAM_Q;

fn main() {
    let np = 4;
    let n = np * (1 << 20);
    let nt = 5;

    // ---- 1. three-layer compose proof (PJRT artifacts) ----
    println!("[1/5] PJRT artifacts (L1 Pallas → L2 JAX → L3 rust)");
    match distarray::runtime::PjrtRuntime::load("artifacts") {
        Ok(rt) => {
            let a = vec![1.0f64; rt.n()];
            let (a2, b2, c2) = rt.run(&a, STREAM_Q).expect("run artifact");
            let errs = rt.validate(&a2, &b2, &c2, STREAM_Q).expect("validate artifact");
            println!(
                "      platform={} n={} nt={} errs=[{:.1e} {:.1e} {:.1e}]",
                rt.platform(),
                rt.n(),
                rt.nt(),
                errs[0],
                errs[1],
                errs[2]
            );
            assert!(errs.iter().all(|e| *e < 1e-9), "PJRT numerics diverged");
        }
        Err(e) => {
            println!("      SKIPPED ({e}) — run `make artifacts` first");
        }
    }

    // ---- 2. coordinated run, block map ----
    println!("[2/5] coordinated STREAM (leader/worker, block map)");
    let agg_block = coordinated(np, n, nt, MapKind::Block);
    println!(
        "      Np={np} triad {} validated={}",
        fmt_bw(agg_block.triad_bw()),
        agg_block.all_valid
    );
    assert!(agg_block.all_valid);

    // ---- 3. map independence: cyclic map, same program ----
    println!("[3/5] map independence (cyclic map, same program)");
    let agg_cyc = coordinated(np, n, nt, MapKind::Cyclic);
    println!(
        "      Np={np} triad {} validated={}",
        fmt_bw(agg_cyc.triad_bw()),
        agg_cyc.all_valid
    );
    assert!(agg_cyc.all_valid);

    // ---- 4. bounded communication: explicit remap ----
    println!("[4/5] global assignment with mismatched maps (remap)");
    let world = ChannelHub::world(np);
    let handles: Vec<_> = world
        .into_iter()
        .map(|t| {
            std::thread::spawn(move || {
                let pid = t.pid();
                let src = Darray::from_global_fn(Dmap::block_1d(np), &[1 << 18], pid, |g| g as f64);
                let mut dst = Darray::zeros(Dmap::cyclic_1d(np), &[1 << 18], pid);
                dst.assign_from(&src, &t, 7).unwrap();
                // spot-check correctness on owned elements
                for g in (pid..1 << 18).step_by(1 << 12) {
                    if let Some(v) = dst.global_get(g) {
                        assert_eq!(v, g as f64);
                    }
                }
                t.stats().bytes_sent()
            })
        })
        .collect();
    let total_bytes: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    println!("      remap moved {total_bytes} bytes over the transport (bounded, explicit)");
    assert!(total_bytes > 0);

    // ---- 5. reports ----
    println!("[5/5] regenerate headline ratios");
    let (core, node, gpu) = fig4::headline_ratios();
    println!("      core 20y = {core:.1}x, node 20y = {node:.1}x, gpu ~5y = {gpu:.1}x");

    println!("\nstream_e2e OK — all layers compose");
}

fn coordinated(np: usize, n: usize, nt: usize, map: MapKind) -> distarray::stream::AggregateResult {
    let cfg = RunConfig {
        n_global: n,
        nt,
        q: STREAM_Q,
        map,
        engine: EngineKind::Native,
        dtype: distarray::element::Dtype::F64,
        backend: distarray::backend::BackendKind::Host,
        threads: 1,
        coll: distarray::collective::CollKind::Star,
        nppn: 0,
        chunk_bytes: 0,
        artifacts: "artifacts".into(),
    };
    let mut world = ChannelHub::world(np);
    let leader = world.remove(0);
    let handles: Vec<_> = world
        .into_iter()
        .map(|t| std::thread::spawn(move || run_worker(&t).unwrap()))
        .collect();
    let (agg, _) = run_leader(&leader, &cfg).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    agg
}
