//! Petascale simulation — the paper's closing headline: "Running on
//! hundreds of MIT SuperCloud nodes simultaneously achieved a
//! sustained bandwidth >1 PB/s."
//!
//! Sweeps a SuperCloud-like CPU+GPU node mix with the analytic model
//! (horizontal scaling is exactly linear — the same-map design
//! communicates nothing) and reports the PB/s crossing.
//!
//! ```text
//! cargo run --release --example petascale_sim [--max-nodes 2048]
//! ```

use distarray::cli::Args;
use distarray::report::petascale;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_nodes = args.flag_usize("max-nodes", 1024);
    print!("{}", petascale::render(max_nodes));
    match petascale::nodes_to_reach(1e15, max_nodes.max(4096)) {
        Some(n) => println!("petascale_sim OK — PB/s at {n} nodes"),
        None => println!("petascale_sim: PB/s not reached (increase --max-nodes)"),
    }
}
