//! dtype sweep — the mixed-precision bandwidth lever on one machine.
//!
//! The paper's §III bytes-per-iteration formulas generalize from the
//! literal 8-byte double to any element width `W`: Copy/Scale move
//! `2·W·N` bytes, Add/Triad `3·W·N`. At equal bytes/second an f32
//! STREAM therefore moves ~2× the *elements*/second of f64 — the key
//! lever behind the temporal-hardware comparisons, now reproducible
//! directly:
//!
//! ```text
//! cargo run --release --example dtype_sweep [-- --np 4 --n-per-p 2097152 --nt 8]
//! ```

use distarray::cli::Args;
use distarray::dmap::Dmap;
use distarray::report::fmt_bw;
use distarray::stream::{run_parallel_spmd_t, AggregateResult, STREAM_Q};

fn row(label: &str, agg: &AggregateResult) {
    println!(
        "  {label:<4} triad {:>12}   {:>10.3e} elem/s   {}B/elem   validated={}",
        fmt_bw(agg.triad_bw()),
        agg.triad_elements_per_sec(),
        agg.width,
        agg.all_valid
    );
}

fn main() {
    let args = Args::from_env();
    let np = args.flag_usize("np", 4);
    let n = np * args.flag_usize("n-per-p", 1 << 21);
    let nt = args.flag_usize("nt", 8);
    let map = Dmap::block_1d(np);

    println!("dtype sweep: Np={np} N={n} Nt={nt} (block map, in-process SPMD)");

    let agg64 = run_parallel_spmd_t::<f64>(&map, n, nt, STREAM_Q);
    row("f64", &agg64);
    assert!(agg64.all_valid, "f64 run failed §III closed-form checks");

    let agg32 = run_parallel_spmd_t::<f32>(&map, n, nt, STREAM_Q as f32);
    row("f32", &agg32);
    assert!(agg32.all_valid, "f32 run failed §III closed-form checks");

    // The arithmetic identity: per byte of bandwidth, f32 streams
    // exactly 2× the elements of f64 (widths 4 vs 8).
    let per_byte_64 = agg64.triad_elements_per_sec() / agg64.triad_bw();
    let per_byte_32 = agg32.triad_elements_per_sec() / agg32.triad_bw();
    let ratio = per_byte_32 / per_byte_64;
    println!("\n  elements-per-byte ratio f32/f64 = {ratio:.3} (exact: 2.000)");
    assert!((ratio - 2.0).abs() < 1e-9);

    // The measured lever: both dtypes saturate roughly the same
    // memory bandwidth, so wall-clock elements/sec should land well
    // above 1× — report it, and sanity-bound it loosely (machine
    // noise, cache effects at small N).
    let elem_speedup = agg32.triad_elements_per_sec() / agg64.triad_elements_per_sec();
    println!("  measured elements/sec speedup f32 over f64 = {elem_speedup:.2}x (ideal ≈ 2x)");

    println!("\ndtype_sweep OK");
}
