//! Map gallery — Figure 1: "Different parallel mappings of a
//! two-dimensional array. Arrays can be broken up in any dimension."
//!
//! Renders the ownership of an 8×8 matrix under the four mappings the
//! figure shows: block rows, block columns, block rows+columns, and
//! block columns with overlap.

use distarray::dmap::{Dist, Dmap, Grid, Overlap, Partition};

fn render(map: &Dmap, shape: &[usize], title: &str) {
    println!("-- {title} --");
    let (rows, cols) = (shape[0], shape[1]);
    for i in 0..rows {
        let mut line = String::new();
        for j in 0..cols {
            let pid = map.owner(&[i, j], shape);
            line.push_str(&format!("{pid} "));
        }
        println!("  {line}");
    }
    println!();
}

fn main() {
    let shape = [8usize, 8];

    // Figure 1, panel 1: broken up by rows.
    render(&Dmap::block_2d(4, 1), &shape, "block rows    map([4 1], {}, 0:3)");

    // Panel 2: broken up by columns.
    render(&Dmap::block_2d(1, 4), &shape, "block columns map([1 4], {}, 0:3)");

    // Panel 3: rows and columns.
    render(&Dmap::block_2d(2, 2), &shape, "block grid    map([2 2], {}, 0:3)");

    // Panel 4: columns with overlap — boundaries stored on two PIDs.
    let overlap_map = Dmap::new(
        Grid::new(&[1, 4]),
        vec![Dist::Block, Dist::Block],
        vec![Overlap::none(), Overlap::new(1)],
        (0..4).collect(),
    );
    render(&overlap_map, &shape, "block columns + overlap 1 (owned view)");
    for pid in 0..4 {
        println!(
            "  pid {pid}: owns {:?}, stores {:?} (halo shares the boundary)",
            overlap_map.local_shape(pid, &shape),
            overlap_map.stored_shape(pid, &shape)
        );
    }

    // Cyclic and block-cyclic variants (§II "maps can become quite
    // complex and express virtually arbitrary distributions").
    println!();
    render(&cyclic_cols(), &shape, "cyclic columns");
    render(&block_cyclic_cols(2), &shape, "block-cyclic columns (bs=2)");

    // Ownership is a partition: every element has exactly one owner.
    for map in [Dmap::block_2d(2, 2), cyclic_cols()] {
        let p = Partition::of(&map, &shape);
        let covered: usize = p.ranges().iter().map(|(_, r)| r.len()).sum();
        assert_eq!(covered, 64);
    }
    println!("map_gallery OK");
}

fn cyclic_cols() -> Dmap {
    Dmap::new(
        Grid::new(&[1, 4]),
        vec![Dist::Block, Dist::Cyclic],
        vec![Overlap::none(), Overlap::none()],
        (0..4).collect(),
    )
}

fn block_cyclic_cols(bs: usize) -> Dmap {
    Dmap::new(
        Grid::new(&[1, 4]),
        vec![Dist::Block, Dist::BlockCyclic { block_size: bs }],
        vec![Overlap::none(), Overlap::none()],
        (0..4).collect(),
    )
}
