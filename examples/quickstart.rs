//! Quickstart — the paper's Code Listing 1/2 in this library.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the `map([1 Np], {}, 0:Np-1)` distributed vectors, runs
//! parallel STREAM on every PID (one thread each), validates against
//! the §III closed forms, and prints per-op aggregate bandwidth.

use distarray::dmap::Dmap;
use distarray::report::fmt_bw;
use distarray::stream::{run_parallel_spmd, STREAM_Q};

fn main() {
    let np = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let n = np * (1 << 21); // N = Np * local (constant N/Np, Table II rule)
    let nt = 10;

    println!("Parallel STREAM via distributed arrays");
    println!("  Np = {np}, N = {n} (N/Np = 2^21), Nt = {nt}, q = √2−1\n");

    // ABCmap = map([1 Np], {}, 0:Np-1)  — the Code Listing map.
    let map = Dmap::block_1d(np);
    let agg = run_parallel_spmd(&map, n, nt, STREAM_Q);

    println!("  copy : {:>12}", fmt_bw(agg.bw[0]));
    println!("  scale: {:>12}", fmt_bw(agg.bw[1]));
    println!("  add  : {:>12}", fmt_bw(agg.bw[2]));
    println!("  triad: {:>12}", fmt_bw(agg.bw[3]));
    println!("\n  validated: {} (worst err {:.2e})", agg.all_valid, agg.worst_err);
    assert!(agg.all_valid);
    println!("\nquickstart OK");
}
