//! Jacobi stencil over an overlap map — Figure 1's fourth panel doing
//! real work: "One example of this complexity is the case in which a
//! boundary of an array is required by more than one PID and will be
//! implicitly communicated to complete the computation" (§II).
//!
//! 1-D heat diffusion `u' = u + α (u[i-1] - 2u[i] + u[i+1])` with
//! fixed boundaries, distributed over a block map with overlap 1:
//! each sweep reads one neighbour cell on each side; the right halo
//! comes from the split `sync_halo_send`/`sync_halo_recv` pair, the
//! left boundary value is exchanged symmetrically. Each sweep pushes
//! its boundary messages first, computes the interior cells while
//! they are in flight, and only then waits for the two edge inputs —
//! the compute/communication overlap pattern at example scale. The
//! distributed result is compared element-for-element against a
//! serial reference.
//!
//! ```text
//! cargo run --release --example jacobi_stencil
//! ```

use distarray::comm::{ChannelHub, Transport, WireReader, WireWriter};
use distarray::darray::Darray;
use distarray::dmap::Dmap;
use std::thread;

const ALPHA: f64 = 0.25;
const TAG_LEFT: u64 = 0x1EF7;

fn serial_reference(n: usize, sweeps: usize) -> Vec<f64> {
    let mut u: Vec<f64> = (0..n).map(init).collect();
    let mut v = u.clone();
    for _ in 0..sweeps {
        for i in 1..n - 1 {
            v[i] = u[i] + ALPHA * (u[i - 1] - 2.0 * u[i] + u[i + 1]);
        }
        std::mem::swap(&mut u, &mut v);
    }
    u
}

fn init(g: usize) -> f64 {
    if g % 37 == 0 {
        100.0
    } else {
        0.0
    }
}

fn main() {
    let np = 4;
    let n = 4 * 1000;
    let sweeps = 50;

    let world = ChannelHub::world(np);
    let handles: Vec<_> = world
        .into_iter()
        .map(|t| thread::spawn(move || run_pid(&t, np, n, sweeps)))
        .collect();
    let pieces: Vec<(usize, Vec<f64>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Stitch the distributed result and compare with serial.
    let want = serial_reference(n, sweeps);
    let mut got = vec![0.0; n];
    for (lo, piece) in pieces {
        got[lo..lo + piece.len()].copy_from_slice(&piece);
    }
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f64, f64::max);
    println!("jacobi: n={n} sweeps={sweeps} np={np} max|dist - serial| = {max_err:.3e}");
    assert!(max_err < 1e-11, "distributed stencil diverged");
    println!("jacobi_stencil OK — overlap map + halo sync reproduce the serial stencil");
}

/// One PID's distributed sweep loop. Returns (global_lo, final local values).
fn run_pid(t: &dyn Transport, np: usize, n: usize, sweeps: usize) -> (usize, Vec<f64>) {
    let me = t.pid();
    let map = Dmap::block_1d_overlap(np, 1);
    let mut u = Darray::from_global_fn(map.clone(), &[n], me, init);
    let owned = u.local_len();
    let block = n / np; // uniform here
    let glo = me * block;

    let mut next = vec![0.0f64; owned];
    for sweep in 0..sweeps {
        let tag_left = TAG_LEFT ^ ((sweep as u64) << 16);
        // Push both boundary messages before touching any cell: my
        // first cell to the left neighbour's halo slot, my last cell
        // to the right neighbour's left input.
        u.sync_halo_send(t, sweep as u64).unwrap();
        if me + 1 < np {
            let mut w = WireWriter::new();
            w.put_f64(u.loc()[owned - 1]);
            t.send(me + 1, tag_left, &w.finish()).unwrap();
        }

        // Compute-on-arrival at example scale: the interior cells
        // read only owned memory, so they sweep while the boundary
        // exchanges are still in flight.
        {
            let stored = u.stored();
            for i in 1..owned.saturating_sub(1) {
                next[i] =
                    stored[i] + ALPHA * (stored[i - 1] - 2.0 * stored[i] + stored[i + 1]);
            }
        }

        // Land the remote cells and finish the two edge cells.
        u.sync_halo_recv(t, sweep as u64).unwrap();
        let left_val = if me > 0 {
            let payload = t.recv(me - 1, tag_left).unwrap();
            Some(WireReader::new(&payload).get_f64().unwrap())
        } else {
            None
        };
        {
            let stored = u.stored();
            let mut edge = |i: usize| {
                let g = glo + i;
                if g == 0 || g == n - 1 {
                    next[i] = stored[i]; // fixed boundary
                    return;
                }
                let left = if i == 0 {
                    left_val.expect("interior PID has a left neighbour")
                } else {
                    stored[i - 1]
                };
                // stored[owned] is the halo cell (right neighbour's
                // first) — the i == owned-1 read lands there.
                next[i] = stored[i] + ALPHA * (left - 2.0 * stored[i] + stored[i + 1]);
            };
            edge(0);
            if owned > 1 {
                edge(owned - 1);
            }
        }
        u.loc_mut().copy_from_slice(&next);
    }
    (glo, u.loc().to_vec())
}
