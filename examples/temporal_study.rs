//! Temporal study — Figures 3 and 4 end to end: simulate every era ×
//! language panel, measure a real series on this machine, and print
//! the temporal-scaling table with the paper's headline ratios.
//!
//! ```text
//! cargo run --release --example temporal_study
//! ```

use distarray::hardware::{Era, Lang};
use distarray::report::{fig3, fig4, fmt_bw};

fn main() {
    // Figure 3: one panel per era, three languages.
    println!("== Figure 3 (simulated panels, triad bandwidth vs Np) ==\n");
    for label in ["xeon-p4", "bg-p", "xeon-e5", "xeon-g6", "xeon-p8", "amd-e9"] {
        let era = Era::by_label(label).unwrap();
        println!("{label} ({}):", era.year);
        for lang in Lang::ALL {
            let s = fig3::simulate_series(era, lang);
            let pts: Vec<String> = s
                .points
                .iter()
                .map(|p| format!("{}@{}", fmt_bw(p.triad_bw), p.np))
                .collect();
            println!("  {:<7} {}", lang.name(), pts.join("  "));
        }
    }
    println!("\nGPU nodes:");
    for label in ["v100", "h100nvl"] {
        let era = Era::by_label(label).unwrap();
        let s = fig3::simulate_series(era, Lang::Python);
        for p in &s.points {
            println!("  {label} Np={} triad {}", p.np, fmt_bw(p.triad_bw));
        }
    }

    // Real measured series on this machine — same reporting path.
    println!("\n== measured on this machine (native engine) ==");
    let max_np = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let s = fig3::measured_series(max_np, 1 << 21, 5);
    for p in &s.points {
        println!("  Np={:<3} triad {}", p.np, fmt_bw(p.triad_bw));
    }

    // Figure 4.
    println!("\n== Figure 4 ==\n{}", fig4::render());
    println!("temporal_study OK");
}
